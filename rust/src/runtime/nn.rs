//! Width-generic numerical TGNN for the reference backend: forward,
//! analytic backward, and a real Adam step — pure Rust, no dependencies.
//!
//! This is the math behind `reference://syn_*` steps ([`super::RefExec`]
//! dispatches here). One architecture covers both synthetic variants:
//!
//! - **Time encoding**: a fixed sinusoidal basis `φ_k(Δt) = cos(Δt ·
//!   dt_scale / 3^k)`, k < `dte` — no learned parameters (TGAT's Bochner
//!   encoding with frozen frequencies).
//! - **GRU memory updater** (memory variants): `m̃_v = GRU([mail_v,
//!   φ(Δt_mail)], s_v)`, gated by `mail_mask` so mail-less nodes keep
//!   their memory — TGN Eq. 1–3 with the mailbox decoupling.
//! - **Input projection**: `x_v = tanh(W_in [m̃_v, feat_v, φ(Δt_mem)] +
//!   b_in)` — the memory-age term encodes staleness (TGN's `Φ(t − t_v^-)`)
//!   and makes every embedding sensitive to the `mem_dt` state gather.
//! - **Single-head temporal attention** per hop (weights shared across
//!   hops): queries from the target's projection, keys/values from
//!   `[h_u, φ(Δt_uv), efeat_uv]` over the sampled neighbors, softmax over
//!   valid slots, combined as `h_v = tanh(W_s x_v + W_a Σ α_u v_u + b_o)`.
//! - **Link decoder**: 2-layer MLP on `[z_src, z_dst]` with BCE-with-
//!   logits loss over positive and corrupted destinations.
//! - **Node classifier** (`clf` step): softmax/cross-entropy MLP on
//!   harvested embeddings.
//!
//! # Width-generic layout
//!
//! Nothing here is frozen at toy sizes: the module widths live in
//! [`NnDims`] — embedding width `dh`, time-encoding width `dte`, decoder
//! hidden `dd`, classifier hidden `ch` — carried in the query string of
//! the step's `hlo` URI (`reference://syn_tgn/train?dh=100&dte=4&...`),
//! with the remaining dims (`dv`, `de`, `dm`, `maild`, fanout, hops) read
//! off the input shapes as before. [`Layout`] derives every weight
//! matrix's offset from those dims, so the lowering side
//! (`models::synthetic`, which owns the width knob) and this executor
//! always agree; [`tgnn_param_count`]/[`clf_param_count`] are the single
//! source of truth. Dims are sanity-capped at [`MAX_DIM`]; anything over
//! it is a typed, named [`DimCapError`] at spec-parse time — never a
//! panic inside a producer thread.
//!
//! # Kernels, batch tiles, and scratch
//!
//! The hot kernels come from [`super::simd`]: portable 8-lane loops with
//! scalar tails, bitwise-identical to the scalar reference for all
//! accumulate kernels and ULP-bounded for the reassociated reductions
//! (see that module's determinism contract). Since the batch-blocked
//! GEMM backend, forward and backward are phrased over **batch tiles**
//! rather than one root row at a time: an [`ExecCtx`] splits the node
//! rows (and each hop level's attention targets) into up to
//! [`MAX_TILES`] contiguous tiles, each walked in `TILE_ROWS`-row blocks
//! by the `gemm` / `gemm_acc` / `gemm_t_acc` / `outer_acc_block`
//! kernels, so every weight matrix streams from cache once per block
//! instead of once per row. Tiles run on the caller's
//! [`WorkerPool`](crate::util::pool::WorkerPool) ([`super::RefExec`]
//! owns it); chunk boundaries are a pure function of the row count and
//! tile count, never of scheduling.
//!
//! **Determinism across tile counts.** Tile count 1 executes inline and
//! is *bitwise identical* to the pre-tiling serial executor: the GEMM
//! kernels perform element-for-element the same operation sequence as
//! the per-row matvec loops they replace, and the serial path
//! accumulates gradients straight into the single gradient vector in
//! the original row order. Multi-tile runs accumulate into per-tile
//! gradient buffers reduced in **fixed tile order** (and reduce the
//! loss from per-tile `f64` partials the same way), so a fixed tile
//! count is run-to-run deterministic, and ULP-bounded against serial —
//! both pinned by `rust/tests/pipeline_identity.rs`.
//!
//! All per-row/per-block scratch lives in a pooled scratch arena: tile
//! workers take block-sized buffers from the shared [`TensorPool`]
//! (recycled across steps, no 64-float stack ceiling), which keeps the
//! steady-state guarantee: once the pool is warm a train step performs
//! **zero heap allocations** at any width and any tile count
//! (`rust/tests/alloc_train.rs` proves widths 8 and 100, serial and
//! tiled).
//!
//! Training steps backpropagate through all of the above with
//! hand-derived gradients (verified against finite differences in the
//! tests below, at widths 8 and 12 here and width 100 in
//! `rust/tests/width100.rs`) and apply a bias-corrected Adam update;
//! `new_mem` / `new_mail` persist the refreshed memory and partner
//! messages (stop-gradient across batches, as in TGN/TGL). Everything is
//! a pure, deterministic function of the inputs — bitwise identical
//! across execution modes.

// lint: allow-file(index, "dense kernels index row-major buffers sized by layer dims at construction; loop ranges are the bounds")

#![allow(clippy::needless_range_loop)] // index-heavy kernels: ranges are clearer

use super::manifest::StepSpec;
use super::simd::{
    axpy, dot, gemm, gemm_acc, gemm_t_acc, matvec, matvec_t_acc, outer_acc, outer_acc_block, vadd,
};
use super::tensor::Tensor;
use crate::util::pool::WorkerPool;
use crate::util::tensor_pool::{PoolBuf, TensorPool};
use anyhow::{bail, ensure, Result};
use std::ops::Range;

/// Adam hyper-parameters (the standard defaults).
const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Bound for the fixed hop-level bookkeeping arrays.
const MAX_HOPS: usize = 4;

/// Sanity cap on every model dim (and each derived scratch width such as
/// `ki = dh + dte + de`). The scratch arena is pooled, so this is not a
/// hard memory limit — it exists to catch corrupt or absurd configs with
/// a typed, named error ([`DimCapError`]) instead of an over-allocation
/// deep inside a producer thread.
pub const MAX_DIM: usize = 2048;

/// Largest class count the `clf` step supports. 192 covers the paper's
/// multi-class tasks, GDELT (81) and MAG (152); public so
/// `models::synthetic` can validate a dataset's `num_classes` before
/// building a variant.
pub const MAX_CLASSES: usize = 192;

/// A model dim (or derived scratch width) exceeded [`MAX_DIM`]. Carries
/// the offending dim by name so callers — `RunPlan`, the synthetic model
/// builders, producer supervisors — can report exactly which knob to fix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DimCapError {
    pub what: &'static str,
    pub dim: usize,
    pub cap: usize,
}

impl std::fmt::Display for DimCapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "reference nn: dim `{}` = {} exceeds the scratch cap {} (MAX_DIM)",
            self.what, self.dim, self.cap
        )
    }
}

impl std::error::Error for DimCapError {}

/// Return a typed [`DimCapError`] if `dim` exceeds [`MAX_DIM`].
pub fn check_dim(what: &'static str, dim: usize) -> Result<()> {
    if dim > MAX_DIM {
        return Err(anyhow::Error::new(DimCapError { what, dim, cap: MAX_DIM }));
    }
    Ok(())
}

/// The four module widths that are a property of the *model config*, not
/// of any input tensor shape: embedding width `dh`, sinusoidal
/// time-encoding width `dte`, link-decoder hidden width `dd`, and
/// node-classifier hidden width `ch`. Carried in the query string of the
/// step's `hlo` URI; defaults reproduce the legacy frozen-dim network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NnDims {
    pub dh: usize,
    pub dte: usize,
    pub dd: usize,
    pub ch: usize,
}

impl Default for NnDims {
    fn default() -> Self {
        NnDims { dh: 8, dte: 4, dd: 8, ch: 8 }
    }
}

impl NnDims {
    /// Parse dims from an `hlo` URI query string, e.g.
    /// `reference://syn_tgn/train?dh=100&dte=4&dd=100&ch=8`. A URI
    /// without a query yields the defaults. Allocation-free on success.
    pub fn from_hlo(hlo: &str) -> Result<NnDims> {
        let mut d = NnDims::default();
        let Some((_, query)) = hlo.split_once('?') else {
            return Ok(d);
        };
        for kv in query.split('&') {
            if kv.is_empty() {
                continue;
            }
            let Some((key, val)) = kv.split_once('=') else {
                bail!("reference nn: malformed dim pair `{kv}` in `{hlo}`");
            };
            let n: usize = val
                .parse()
                .map_err(|_| anyhow::anyhow!("reference nn: bad value for dim `{key}`: `{val}`"))?;
            match key {
                "dh" => d.dh = n,
                "dte" => d.dte = n,
                "dd" => d.dd = n,
                "ch" => d.ch = n,
                other => bail!("reference nn: unknown dim `{other}` in `{hlo}`"),
            }
        }
        d.validate()?;
        Ok(d)
    }

    /// Every width ≥ 1 and under [`MAX_DIM`] (typed error otherwise).
    pub fn validate(&self) -> Result<()> {
        for (what, v) in
            [("dh", self.dh), ("dte", self.dte), ("dd", self.dd), ("ch", self.ch)]
        {
            ensure!(v >= 1, "reference nn: dim `{what}` must be >= 1");
            check_dim(what, v)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Parameter layout
// ---------------------------------------------------------------------

/// Byte-free offset bookkeeping for the flat parameter vector.
struct Off(usize);

impl Off {
    fn take(&mut self, n: usize) -> usize {
        let o = self.0;
        self.0 += n;
        o
    }
}

/// Offsets of every weight matrix inside the flat `params` vector.
/// Row-major matrices; the layout is a pure function of the dims, so the
/// lowering side (`models::synthetic`) and this executor always agree.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Layout {
    /// GRU input width: `maild + dte`.
    gi: usize,
    /// Projection input width: `dm + dv + dte` (memory: m̃, features,
    /// memory-age encoding) or `dv`.
    ui: usize,
    /// Attention key/value input width: `dh + dte + de`.
    ki: usize,
    w_r: usize,
    u_r: usize,
    b_r: usize,
    w_z: usize,
    u_z: usize,
    b_z: usize,
    w_n: usize,
    u_n: usize,
    b_n: usize,
    w_in: usize,
    b_in: usize,
    w_q: usize,
    w_k: usize,
    w_v: usize,
    w_s: usize,
    w_a: usize,
    b_o: usize,
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
    total: usize,
}

fn layout(
    d: &NnDims,
    use_memory: bool,
    dv: usize,
    de: usize,
    dm: usize,
    maild: usize,
) -> Layout {
    let (dh, dte, dd) = (d.dh, d.dte, d.dd);
    let gi = maild + dte;
    let ui = if use_memory { dm + dv + dte } else { dv };
    let ki = dh + dte + de;
    let mut o = Off(0);
    let (w_r, u_r, b_r, w_z, u_z, b_z, w_n, u_n, b_n) = if use_memory {
        (
            o.take(dm * gi),
            o.take(dm * dm),
            o.take(dm),
            o.take(dm * gi),
            o.take(dm * dm),
            o.take(dm),
            o.take(dm * gi),
            o.take(dm * dm),
            o.take(dm),
        )
    } else {
        (0, 0, 0, 0, 0, 0, 0, 0, 0)
    };
    let w_in = o.take(dh * ui);
    let b_in = o.take(dh);
    let w_q = o.take(dh * dh);
    let w_k = o.take(dh * ki);
    let w_v = o.take(dh * ki);
    let w_s = o.take(dh * dh);
    let w_a = o.take(dh * dh);
    let b_o = o.take(dh);
    let w1 = o.take(dd * 2 * dh);
    let b1 = o.take(dd);
    let w2 = o.take(dd);
    let b2 = o.take(1);
    Layout {
        gi,
        ui,
        ki,
        w_r,
        u_r,
        b_r,
        w_z,
        u_z,
        b_z,
        w_n,
        u_n,
        b_n,
        w_in,
        b_in,
        w_q,
        w_k,
        w_v,
        w_s,
        w_a,
        b_o,
        w1,
        b1,
        w2,
        b2,
        total: o.0,
    }
}

/// Parameter count of the TGNN train/eval step for the given dims — the
/// single source of truth for `models::synthetic`'s `param_count`.
pub fn tgnn_param_count(
    d: &NnDims,
    use_memory: bool,
    dv: usize,
    de: usize,
    dm: usize,
    maild: usize,
) -> usize {
    layout(d, use_memory, dv, de, dm, maild).total
}

/// Parameter count of the `clf` step MLP (`W1[ch,dh] b1 W2[classes,ch]
/// b2`).
pub fn clf_param_count(d: &NnDims, classes: usize) -> usize {
    d.ch * d.dh + d.ch + classes * d.ch + classes
}

// ---------------------------------------------------------------------
// Non-kernel scalar helpers
// ---------------------------------------------------------------------

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Numerically stable `ln(1 + e^x)`.
#[inline]
fn softplus(x: f32) -> f32 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// Fixed sinusoidal time encoding: `out[k] = cos(dt·scale / 3^k)`.
#[inline]
fn time_enc(dt: f32, scale: f32, out: &mut [f32]) {
    let t = dt * scale;
    let mut w = 1.0f32;
    for o in out.iter_mut() {
        *o = (t * w).cos();
        w *= 1.0 / 3.0;
    }
}

/// Bias-corrected Adam: writes `new_params` / `new_m` / `new_v` from the
/// current state and gradient.
#[allow(clippy::too_many_arguments)]
fn adam(
    p: &[f32],
    m: &[f32],
    v: &[f32],
    g: &[f32],
    lr: f32,
    step: f32,
    np: &mut [f32],
    nm: &mut [f32],
    nv: &mut [f32],
) {
    let t = step + 1.0;
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    for k in 0..p.len() {
        let gk = g[k];
        let mk = BETA1 * m[k] + (1.0 - BETA1) * gk;
        let vk = BETA2 * v[k] + (1.0 - BETA2) * gk * gk;
        nm[k] = mk;
        nv[k] = vk;
        np[k] = p[k] - lr * (mk / bc1) / ((vk / bc2).sqrt() + ADAM_EPS);
    }
}

// ---------------------------------------------------------------------
// Spec-derived dimensions and input indices
// ---------------------------------------------------------------------

const NONE: usize = usize::MAX;

/// Everything the TGNN step needs to know about a spec, derived from the
/// input names/shapes (plus the `hlo` dim query) in one pass.
struct Net {
    d: NnDims,
    bs: usize,
    fanout: usize,
    hops: usize,
    dv: usize,
    de: usize,
    dm: usize,
    maild: usize,
    n_total: usize,
    roots: usize,
    pc: usize,
    use_memory: bool,
    /// `lvl_off[l]` = first node row of hop level `l` (level 0 = roots);
    /// `lvl_off[hops] + lvl_size[hops] == n_total`.
    lvl_off: [usize; MAX_HOPS + 1],
    lvl_size: [usize; MAX_HOPS + 1],
    i_params: usize,
    i_adam_m: usize,
    i_adam_v: usize,
    i_step: usize,
    i_lr: usize,
    i_dt_scale: usize,
    i_edge_mask: usize,
    i_node_feat: usize,
    i_batch_efeat: usize,
    i_hop_dt: [usize; MAX_HOPS],
    i_hop_mask: [usize; MAX_HOPS],
    i_hop_efeat: [usize; MAX_HOPS],
    i_mem: usize,
    i_mem_dt: usize,
    i_mail: usize,
    i_mail_dt: usize,
    i_mail_mask: usize,
}

fn hop_level(name: &str, prefix: &str) -> Result<usize> {
    let l: usize = name[prefix.len()..].parse().map_err(|_| {
        anyhow::anyhow!("reference nn: cannot parse hop level from input `{name}`")
    })?;
    ensure!(l < MAX_HOPS, "reference nn: hop level {l} exceeds MAX_HOPS {MAX_HOPS}");
    Ok(l)
}

impl Net {
    fn from_spec(spec: &StepSpec) -> Result<Net> {
        let mut n = Net {
            d: NnDims::from_hlo(&spec.hlo)?,
            bs: 0,
            fanout: 0,
            hops: 0,
            dv: 0,
            de: 0,
            dm: 0,
            maild: 0,
            n_total: 0,
            roots: 0,
            pc: 0,
            use_memory: false,
            lvl_off: [0; MAX_HOPS + 1],
            lvl_size: [0; MAX_HOPS + 1],
            i_params: NONE,
            i_adam_m: NONE,
            i_adam_v: NONE,
            i_step: NONE,
            i_lr: NONE,
            i_dt_scale: NONE,
            i_edge_mask: NONE,
            i_node_feat: NONE,
            i_batch_efeat: NONE,
            i_hop_dt: [NONE; MAX_HOPS],
            i_hop_mask: [NONE; MAX_HOPS],
            i_hop_efeat: [NONE; MAX_HOPS],
            i_mem: NONE,
            i_mem_dt: NONE,
            i_mail: NONE,
            i_mail_dt: NONE,
            i_mail_mask: NONE,
        };
        for (i, ts) in spec.inputs.iter().enumerate() {
            match ts.name.as_str() {
                "params" => {
                    n.i_params = i;
                    n.pc = ts.numel();
                }
                "adam_m" => n.i_adam_m = i,
                "adam_v" => n.i_adam_v = i,
                "step" => n.i_step = i,
                "lr" => n.i_lr = i,
                "dt_scale" => n.i_dt_scale = i,
                "edge_mask" => {
                    n.i_edge_mask = i;
                    n.bs = ts.numel();
                }
                "node_feat" => {
                    ensure!(ts.shape.len() == 2, "node_feat must be rank 2");
                    n.i_node_feat = i;
                    n.n_total = ts.shape[0];
                    n.dv = ts.shape[1];
                }
                "batch_efeat" => {
                    ensure!(ts.shape.len() == 2, "batch_efeat must be rank 2");
                    n.i_batch_efeat = i;
                    n.de = ts.shape[1];
                }
                "mem" => {
                    ensure!(ts.shape.len() == 2, "mem must be rank 2");
                    n.use_memory = true;
                    n.i_mem = i;
                    n.dm = ts.shape[1];
                }
                "mem_dt" => n.i_mem_dt = i,
                "mail" => {
                    ensure!(ts.shape.len() == 2, "mail must be rank 2");
                    n.i_mail = i;
                    n.maild = ts.shape[1];
                }
                "mail_dt" => n.i_mail_dt = i,
                "mail_mask" => n.i_mail_mask = i,
                name if name.starts_with("dt_s0_h") => {
                    let l = hop_level(name, "dt_s0_h")?;
                    ensure!(ts.shape.len() == 2, "hop dt must be rank 2");
                    n.i_hop_dt[l] = i;
                    n.fanout = ts.shape[1];
                    n.hops = n.hops.max(l + 1);
                }
                name if name.starts_with("mask_s0_h") => {
                    n.i_hop_mask[hop_level(name, "mask_s0_h")?] = i;
                }
                name if name.starts_with("efeat_s0_h") => {
                    n.i_hop_efeat[hop_level(name, "efeat_s0_h")?] = i;
                }
                other => bail!("reference nn: unknown input `{other}`"),
            }
        }
        for (idx, what) in [
            (n.i_params, "params"),
            (n.i_adam_m, "adam_m"),
            (n.i_adam_v, "adam_v"),
            (n.i_step, "step"),
            (n.i_lr, "lr"),
            (n.i_dt_scale, "dt_scale"),
            (n.i_edge_mask, "edge_mask"),
            (n.i_node_feat, "node_feat"),
            (n.i_batch_efeat, "batch_efeat"),
        ] {
            ensure!(idx != NONE, "reference nn: spec is missing input `{what}`");
        }
        if n.use_memory {
            for (idx, what) in [
                (n.i_mem_dt, "mem_dt"),
                (n.i_mail, "mail"),
                (n.i_mail_dt, "mail_dt"),
                (n.i_mail_mask, "mail_mask"),
            ] {
                ensure!(idx != NONE, "reference nn: memory spec is missing input `{what}`");
            }
        }
        ensure!(n.hops >= 1 && n.hops <= MAX_HOPS, "reference nn: hops {} unsupported", n.hops);
        ensure!(n.bs >= 1, "reference nn: empty batch");
        ensure!(n.fanout >= 1, "reference nn: bad fanout {}", n.fanout);
        check_dim("fanout", n.fanout)?;
        n.roots = 3 * n.bs;
        let mut off = 0usize;
        let mut size = n.roots;
        for l in 0..=n.hops {
            n.lvl_off[l] = off;
            n.lvl_size[l] = size;
            off += size;
            size *= n.fanout;
        }
        ensure!(
            off == n.n_total,
            "reference nn: node_feat rows {} != hop-tree size {off}",
            n.n_total
        );
        for l in 0..n.hops {
            for (idx, what) in [
                (n.i_hop_dt[l], "dt"),
                (n.i_hop_mask[l], "mask"),
                (n.i_hop_efeat[l], "efeat"),
            ] {
                ensure!(idx != NONE, "reference nn: missing hop-{l} `{what}` input");
            }
            let dts = &spec.inputs[n.i_hop_dt[l]];
            ensure!(
                dts.shape[0] == n.lvl_size[l] && dts.shape[1] == n.fanout,
                "reference nn: hop-{l} dt shape {:?} != [{}, {}]",
                dts.shape,
                n.lvl_size[l],
                n.fanout
            );
        }
        // Every scratch width the step will take from the pool, capped
        // with the offending dim named (see `DimCapError`).
        check_dim("dm", n.dm)?;
        check_dim("maild", n.maild)?;
        let lo = layout(&n.d, n.use_memory, n.dv, n.de, n.dm, n.maild);
        check_dim("gi (maild + dte)", lo.gi)?;
        check_dim("ui (dm + dv + dte)", lo.ui)?;
        check_dim("ki (dh + dte + de)", lo.ki)?;
        ensure!(
            n.pc == lo.total,
            "reference nn: params has {} floats, layout wants {}",
            n.pc,
            lo.total
        );
        Ok(n)
    }
}

// ---------------------------------------------------------------------
// Blocked execution context
// ---------------------------------------------------------------------

/// Upper bound on the batch-tile count (and thus on the fixed-size
/// per-tile bookkeeping — loss partials, gradient-buffer slots).
pub const MAX_TILES: usize = 64;

/// Rows per GEMM block inside a tile: bounds every per-tile scratch
/// buffer at `TILE_ROWS × width` floats while keeping each weight
/// matrix resident in cache across the block.
const TILE_ROWS: usize = 32;

/// How a TGNN step executes: the batch-tile count plus the worker pool
/// the tiles run on. `tiles == 1` / `workers == None` is the serial
/// path (inline, bitwise-identical to the pre-tiling executor).
pub(crate) struct ExecCtx<'a> {
    pub tiles: usize,
    pub workers: Option<&'a WorkerPool>,
}

impl ExecCtx<'_> {
    /// Dispatch `f(tile_idx, item_range)` over `0..n`: inline as a single
    /// tile on the serial path, otherwise as up to `tiles` contiguous
    /// chunks on the worker pool. Chunk boundaries are a pure function of
    /// `n` and the tile count (see [`WorkerPool::run_chunks`]), so a
    /// fixed tile count always produces the same tile→rows assignment.
    /// The dispatch joins before returning — later phases see every
    /// tile's writes.
    fn for_tiles(&self, n: usize, f: impl Fn(usize, Range<usize>) + Sync) {
        match self.workers {
            Some(pool) if self.tiles > 1 => {
                pool.run_chunks(n, n.div_ceil(self.tiles).max(1), f);
            }
            _ => {
                if n > 0 {
                    f(0, 0..n);
                }
            }
        }
    }
}

/// Raw base pointer of a shared row-major `f32` buffer, `Send + Sync` so
/// tile closures can carve out views of their own disjoint row ranges.
///
/// SAFETY: every `for_tiles` dispatch hands each tile a disjoint row
/// range and each buffer row has exactly one owning tile per phase, so
/// no two live mutable views overlap; the dispatch joins before any
/// later phase reads the buffer through a plain borrow.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    fn of(buf: &mut [f32]) -> SendPtr {
        SendPtr(buf.as_mut_ptr())
    }

    /// Mutable view of rows `range` (`stride` floats per row).
    ///
    /// SAFETY: caller guarantees the range is in bounds of the original
    /// buffer and disjoint from every other live view of it.
    unsafe fn rows_mut<'a>(self, stride: usize, range: Range<usize>) -> &'a mut [f32] {
        std::slice::from_raw_parts_mut(self.0.add(range.start * stride), range.len() * stride)
    }

    /// Shared view of rows `range`. SAFETY: as [`Self::rows_mut`], plus
    /// no concurrently live mutable view may overlap the range.
    unsafe fn rows<'a>(self, stride: usize, range: Range<usize>) -> &'a [f32] {
        std::slice::from_raw_parts(self.0.add(range.start * stride), range.len() * stride)
    }
}

/// [`SendPtr`] for the per-tile `f64` loss partials (one slot per tile
/// index, so tile writes never alias).
#[derive(Clone, Copy)]
struct SendPtr64(*mut f64);

unsafe impl Send for SendPtr64 {}
unsafe impl Sync for SendPtr64 {}

// ---------------------------------------------------------------------
// TGNN train/eval step
// ---------------------------------------------------------------------

/// Execute a `train` or `eval` TGNN step (see module docs). `train` is
/// detected from the presence of a `new_params` output; eval steps skip
/// the backward/Adam phase entirely.
pub(crate) fn run_tgnn_step(
    spec: &StepSpec,
    inputs: &[Tensor],
    out: &mut Vec<Tensor>,
    pool: &TensorPool,
    exec: &ExecCtx<'_>,
) -> Result<()> {
    let net = Net::from_spec(spec)?;
    let lo = layout(&net.d, net.use_memory, net.dv, net.de, net.dm, net.maild);
    let (bs, roots, n, fanout, hops) = (net.bs, net.roots, net.n_total, net.fanout, net.hops);
    let (dv, de, dm, maild) = (net.dv, net.de, net.dm, net.maild);
    let (dh, dte, dd) = (net.d.dh, net.d.dte, net.d.dd);
    let (gi, ui, ki) = (lo.gi, lo.ui, lo.ki);

    let p = inputs[net.i_params].as_f32()?;
    let adam_m = inputs[net.i_adam_m].as_f32()?;
    let adam_v = inputs[net.i_adam_v].as_f32()?;
    let step = inputs[net.i_step].scalar_f32()?;
    let lr = inputs[net.i_lr].scalar_f32()?;
    let dt_scale = inputs[net.i_dt_scale].scalar_f32()?;
    let edge_mask = inputs[net.i_edge_mask].as_f32()?;
    let node_feat = inputs[net.i_node_feat].as_f32()?;
    let batch_efeat = inputs[net.i_batch_efeat].as_f32()?;
    let train = spec.outputs.iter().any(|o| o.name == "new_params");

    // ---- Memory update + input projection, batch-tiled. Each tile owns
    // a disjoint node-row range; inside a tile, rows go through in
    // TILE_ROWS blocks so each weight matrix streams from cache once per
    // block instead of once per row. The blocked kernels are bitwise
    // identical to the per-row matvec loops they replace (`super::simd`),
    // so any tile count produces the same m̃/x bits.
    let (mem, mem_dt, mail, mail_dt, mail_mask);
    let (mut mt, mut g_r, mut g_z, mut g_c);
    if net.use_memory {
        mem = inputs[net.i_mem].as_f32()?;
        mem_dt = inputs[net.i_mem_dt].as_f32()?;
        mail = inputs[net.i_mail].as_f32()?;
        mail_dt = inputs[net.i_mail_dt].as_f32()?;
        mail_mask = inputs[net.i_mail_mask].as_f32()?;
        ensure!(mem.len() == n * dm && mail.len() == n * maild, "state input size mismatch");
        ensure!(mem_dt.len() == n, "mem_dt size mismatch");
        ensure!(mail_dt.len() == n && mail_mask.len() == n, "mail dt/mask size mismatch");
        mt = pool.take(n * dm);
        g_r = pool.take(n * dm);
        g_z = pool.take(n * dm);
        g_c = pool.take(n * dm);
    } else {
        mem = &[];
        mem_dt = &[];
        mail = &[];
        mail_dt = &[];
        mail_mask = &[];
        mt = pool.take(0);
        g_r = pool.take(0);
        g_z = pool.take(0);
        g_c = pool.take(0);
    }
    let mut x = pool.take(n * dh);
    {
        let mt_p = SendPtr::of(&mut mt);
        let g_r_p = SendPtr::of(&mut g_r);
        let g_z_p = SendPtr::of(&mut g_z);
        let g_c_p = SendPtr::of(&mut g_c);
        let x_p = SendPtr::of(&mut x);
        exec.for_tiles(n, |_ti, rows| {
            // Per-tile block scratch from the shared pool: recycled
            // buffers, so the steady state stays allocation-free.
            let mut gin_t = pool.take(TILE_ROWS * gi);
            let mut pre_t = pool.take(TILE_ROWS * dm.max(dh));
            let mut rh_t = pool.take(TILE_ROWS * dm);
            let mut u_t = pool.take(TILE_ROWS * ui);
            let mut b0 = rows.start;
            while b0 < rows.end {
                let b1 = (b0 + TILE_ROWS).min(rows.end);
                let t = b1 - b0;
                if net.use_memory {
                    // m̃ = mail_mask·GRU([mail, φ(Δt)], mem) +
                    // (1-mail_mask)·mem, gates saved for the backward.
                    // SAFETY: rows [b0, b1) belong to this tile alone.
                    let (mt_r, g_r_r, g_z_r, g_c_r) = unsafe {
                        (
                            mt_p.rows_mut(dm, b0..b1),
                            g_r_p.rows_mut(dm, b0..b1),
                            g_z_p.rows_mut(dm, b0..b1),
                            g_c_p.rows_mut(dm, b0..b1),
                        )
                    };
                    let mem_b = &mem[b0 * dm..b1 * dm];
                    for (i, row) in (b0..b1).enumerate() {
                        gin_t[i * gi..i * gi + maild]
                            .copy_from_slice(&mail[row * maild..(row + 1) * maild]);
                        time_enc(mail_dt[row], dt_scale, &mut gin_t[i * gi + maild..(i + 1) * gi]);
                    }
                    gemm(&p[lo.w_r..lo.w_r + dm * gi], &gin_t, t, dm, gi, &mut pre_t);
                    gemm_acc(&p[lo.u_r..lo.u_r + dm * dm], mem_b, t, dm, dm, &mut pre_t);
                    for i in 0..t {
                        for k in 0..dm {
                            g_r_r[i * dm + k] = sigmoid(pre_t[i * dm + k] + p[lo.b_r + k]);
                        }
                    }
                    gemm(&p[lo.w_z..lo.w_z + dm * gi], &gin_t, t, dm, gi, &mut pre_t);
                    gemm_acc(&p[lo.u_z..lo.u_z + dm * dm], mem_b, t, dm, dm, &mut pre_t);
                    for i in 0..t {
                        for k in 0..dm {
                            g_z_r[i * dm + k] = sigmoid(pre_t[i * dm + k] + p[lo.b_z + k]);
                        }
                    }
                    for i in 0..t * dm {
                        rh_t[i] = g_r_r[i] * mem_b[i];
                    }
                    gemm(&p[lo.w_n..lo.w_n + dm * gi], &gin_t, t, dm, gi, &mut pre_t);
                    gemm_acc(&p[lo.u_n..lo.u_n + dm * dm], &rh_t, t, dm, dm, &mut pre_t);
                    for (i, row) in (b0..b1).enumerate() {
                        let mk = mail_mask[row];
                        for k in 0..dm {
                            let c = (pre_t[i * dm + k] + p[lo.b_n + k]).tanh();
                            g_c_r[i * dm + k] = c;
                            let gru = (1.0 - g_z_r[i * dm + k]) * c
                                + g_z_r[i * dm + k] * mem_b[i * dm + k];
                            mt_r[i * dm + k] = mk * gru + (1.0 - mk) * mem_b[i * dm + k];
                        }
                    }
                }
                // Projection x = tanh(W_in u + b_in), u = [m̃, feat, φ].
                // SAFETY: same disjoint row range; the GRU views above
                // are out of scope, so reading this tile's m̃ rows back
                // does not overlap a live mutable view.
                let x_r = unsafe { x_p.rows_mut(dh, b0..b1) };
                for (i, row) in (b0..b1).enumerate() {
                    let uo = i * ui;
                    if net.use_memory {
                        let mt_row = unsafe { mt_p.rows(dm, row..row + 1) };
                        u_t[uo..uo + dm].copy_from_slice(mt_row);
                        u_t[uo + dm..uo + dm + dv]
                            .copy_from_slice(&node_feat[row * dv..(row + 1) * dv]);
                        time_enc(mem_dt[row], dt_scale, &mut u_t[uo + dm + dv..uo + ui]);
                    } else {
                        u_t[uo..uo + dv].copy_from_slice(&node_feat[row * dv..(row + 1) * dv]);
                    }
                }
                gemm(&p[lo.w_in..lo.w_in + dh * ui], &u_t, t, dh, ui, &mut pre_t);
                for i in 0..t {
                    for k in 0..dh {
                        x_r[i * dh + k] = (pre_t[i * dh + k] + p[lo.b_in + k]).tanh();
                    }
                }
                b0 = b1;
            }
        });
    }

    // ---- Temporal attention, deepest hop first. Leaf nodes pass their
    // projection through unchanged; interior/root nodes attend over their
    // sampled neighbors' h. Each level's targets are batch-tiled; the
    // `for_tiles` join between levels is the barrier that makes
    // children's h visible to their parents. Key/value inputs are built
    // densely for every slot of a block (masked slots produce finite
    // values that are never read), so W_k/W_v apply as one blocked GEMM
    // per block straight into the global k/v rows.
    let slots_total = n - roots;
    let inner = net.lvl_off[hops]; // rows that act as attention targets
    let mut h = pool.take(n * dh);
    let mut att_a = pool.take(slots_total);
    let mut att_k = pool.take(slots_total * dh);
    let mut att_v = pool.take(slots_total * dh);
    let mut asum = pool.take(inner * dh);
    h[inner * dh..n * dh].copy_from_slice(&x[inner * dh..n * dh]);
    let scale_inv = 1.0 / (dh as f32).sqrt();
    {
        let xs: &[f32] = &x;
        let h_p = SendPtr::of(&mut h);
        let att_a_p = SendPtr::of(&mut att_a);
        let att_k_p = SendPtr::of(&mut att_k);
        let att_v_p = SendPtr::of(&mut att_v);
        let asum_p = SendPtr::of(&mut asum);
        for lev in (0..hops).rev() {
            let dt_in = inputs[net.i_hop_dt[lev]].as_f32()?;
            let mask_in = inputs[net.i_hop_mask[lev]].as_f32()?;
            let ef_in = inputs[net.i_hop_efeat[lev]].as_f32()?;
            let child_base = net.lvl_off[lev + 1];
            let gbase = child_base - roots;
            let lbase = net.lvl_off[lev];
            exec.for_tiles(net.lvl_size[lev], |_ti, targets| {
                let mut qr_t = pool.take(TILE_ROWS * dh);
                let mut kin_t = pool.take(TILE_ROWS * fanout * ki);
                let mut hpre_t = pool.take(TILE_ROWS * dh);
                let mut e = pool.take(fanout);
                let mut b0 = targets.start;
                while b0 < targets.end {
                    let b1 = (b0 + TILE_ROWS).min(targets.end);
                    let t = b1 - b0;
                    // SAFETY: target rows [lbase+b0, lbase+b1) and slot
                    // rows [b0·fanout, b1·fanout) of this level belong to
                    // this tile alone; the h rows read (children) start at
                    // child_base, past every target row written at this
                    // level, and were finalized by the previous level's
                    // dispatch (or the serial leaf copy).
                    let (s0, s1) = (gbase + b0 * fanout, gbase + b1 * fanout);
                    let (c0, c1) = (child_base + b0 * fanout, child_base + b1 * fanout);
                    let h_tgt = unsafe { h_p.rows_mut(dh, lbase + b0..lbase + b1) };
                    let h_child = unsafe { h_p.rows(dh, c0..c1) };
                    let att_k_r = unsafe { att_k_p.rows_mut(dh, s0..s1) };
                    let att_v_r = unsafe { att_v_p.rows_mut(dh, s0..s1) };
                    let att_a_r = unsafe { att_a_p.rows_mut(1, s0..s1) };
                    let asum_r = unsafe { asum_p.rows_mut(dh, lbase + b0..lbase + b1) };
                    let x_tile = &xs[(lbase + b0) * dh..(lbase + b1) * dh];
                    gemm(&p[lo.w_q..lo.w_q + dh * dh], x_tile, t, dh, dh, &mut qr_t);
                    for s in 0..t * fanout {
                        let slot = b0 * fanout + s;
                        let so = s * ki;
                        kin_t[so..so + dh].copy_from_slice(&h_child[s * dh..(s + 1) * dh]);
                        time_enc(dt_in[slot], dt_scale, &mut kin_t[so + dh..so + dh + dte]);
                        kin_t[so + dh + dte..so + ki]
                            .copy_from_slice(&ef_in[slot * de..(slot + 1) * de]);
                    }
                    gemm(&p[lo.w_k..lo.w_k + dh * ki], &kin_t, t * fanout, dh, ki, att_k_r);
                    gemm(&p[lo.w_v..lo.w_v + dh * ki], &kin_t, t * fanout, dh, ki, att_v_r);
                    for i in 0..t {
                        let r0 = b0 + i;
                        let qr = &qr_t[i * dh..(i + 1) * dh];
                        let mut any = false;
                        let mut emax = f32::MIN;
                        for j in 0..fanout {
                            let slot = r0 * fanout + j;
                            if mask_in[slot] <= 0.5 {
                                continue;
                            }
                            let ko = (i * fanout + j) * dh;
                            e[j] = dot(qr, &att_k_r[ko..ko + dh]) * scale_inv;
                            emax = emax.max(e[j]);
                            any = true;
                        }
                        if any {
                            let mut esum = 0.0f32;
                            for j in 0..fanout {
                                let slot = r0 * fanout + j;
                                if mask_in[slot] <= 0.5 {
                                    continue;
                                }
                                let a = (e[j] - emax).exp();
                                att_a_r[i * fanout + j] = a;
                                esum += a;
                            }
                            for j in 0..fanout {
                                let slot = r0 * fanout + j;
                                if mask_in[slot] <= 0.5 {
                                    continue;
                                }
                                let a = att_a_r[i * fanout + j] / esum;
                                att_a_r[i * fanout + j] = a;
                                let vo = (i * fanout + j) * dh;
                                axpy(&mut asum_r[i * dh..(i + 1) * dh], a, &att_v_r[vo..vo + dh]);
                            }
                        }
                    }
                    gemm(&p[lo.w_s..lo.w_s + dh * dh], x_tile, t, dh, dh, &mut hpre_t);
                    gemm_acc(&p[lo.w_a..lo.w_a + dh * dh], asum_r, t, dh, dh, &mut hpre_t);
                    for i in 0..t {
                        for k in 0..dh {
                            h_tgt[i * dh + k] = (hpre_t[i * dh + k] + p[lo.b_o + k]).tanh();
                        }
                    }
                    b0 = b1;
                }
            });
        }
    }

    // ---- Link decoder: s = w2·relu(W1 [z_a, z_b] + b1) + b2, BCE with
    // logits over (src, dst) positives and (src, neg) corruptions.
    // Batch-tiled; each tile sums its loss terms into its own f64 slot
    // in ascending row order, and the slots reduce in fixed tile order —
    // with one tile, slot 0 is exactly the serial accumulator.
    let mut s_p = pool.take(bs);
    let mut s_n = pool.take(bs);
    let mut hid_p = pool.take(bs * dd);
    let mut hid_n = pool.take(bs * dd);
    let wnorm = edge_mask.iter().sum::<f32>().max(1e-6);
    let mut loss_parts = [0.0f64; MAX_TILES];
    {
        let hs: &[f32] = &h;
        let s_p_p = SendPtr::of(&mut s_p);
        let s_n_p = SendPtr::of(&mut s_n);
        let hid_p_p = SendPtr::of(&mut hid_p);
        let hid_n_p = SendPtr::of(&mut hid_n);
        let lp_p = SendPtr64(loss_parts.as_mut_ptr());
        exec.for_tiles(bs, |ti, irange| {
            // SAFETY: one f64 slot per tile index (ti < tiles ≤ MAX_TILES).
            let part = unsafe { &mut *lp_p.0.add(ti) };
            let mut din_t = pool.take(TILE_ROWS * 2 * dh);
            let mut b0 = irange.start;
            while b0 < irange.end {
                let b1 = (b0 + TILE_ROWS).min(irange.end);
                let t = b1 - b0;
                for pass in 0..2 {
                    let boff = if pass == 0 { bs } else { 2 * bs };
                    for (i, row) in (b0..b1).enumerate() {
                        let io = i * 2 * dh;
                        din_t[io..io + dh].copy_from_slice(&hs[row * dh..(row + 1) * dh]);
                        din_t[io + dh..io + 2 * dh]
                            .copy_from_slice(&hs[(boff + row) * dh..(boff + row + 1) * dh]);
                    }
                    // SAFETY: score/hidden rows [b0, b1) belong to this
                    // tile alone.
                    let hid_r = unsafe {
                        if pass == 0 {
                            hid_p_p.rows_mut(dd, b0..b1)
                        } else {
                            hid_n_p.rows_mut(dd, b0..b1)
                        }
                    };
                    let s_r = unsafe {
                        if pass == 0 {
                            s_p_p.rows_mut(1, b0..b1)
                        } else {
                            s_n_p.rows_mut(1, b0..b1)
                        }
                    };
                    gemm(&p[lo.w1..lo.w1 + dd * 2 * dh], &din_t, t, dd, 2 * dh, hid_r);
                    for i in 0..t {
                        let hid = &mut hid_r[i * dd..(i + 1) * dd];
                        for k in 0..dd {
                            hid[k] = (hid[k] + p[lo.b1 + k]).max(0.0);
                        }
                        s_r[i] = p[lo.b2] + dot(&p[lo.w2..lo.w2 + dd], hid);
                    }
                }
                // SAFETY: shared read-back of this tile's own score rows;
                // the mutable views above are out of scope.
                let (sp_r, sn_r) = unsafe { (s_p_p.rows(1, b0..b1), s_n_p.rows(1, b0..b1)) };
                for (i, row) in (b0..b1).enumerate() {
                    *part += (edge_mask[row] * (softplus(-sp_r[i]) + softplus(sn_r[i]))) as f64
                        / wnorm as f64;
                }
                b0 = b1;
            }
        });
    }
    let loss = loss_parts.iter().sum::<f64>() as f32;

    // ---- Backward + Adam (train steps only).
    let (mut new_p, mut new_m, mut new_v) = (None, None, None);
    if train {
        // Gradient accumulation: on the serial path every phase
        // accumulates straight into `g` in the original row order
        // (bitwise-identical to the pre-tiling executor — no per-tile
        // buffer detour, which would flip `-0.0` contributions to
        // `+0.0`). With worker tiles, each tile owns a `pc`-sized slice
        // of `gbufs`, reduced into `g` in fixed tile order afterwards.
        let par = exec.workers.is_some() && exec.tiles > 1;
        let mut g = pool.take(net.pc);
        let mut gbufs = pool.take(if par { exec.tiles * net.pc } else { 0 });
        let mut dh_buf = pool.take(n * dh);
        let mut dx_buf = pool.take(n * dh);
        let g_p = SendPtr::of(&mut g);
        let gb_p = SendPtr::of(&mut gbufs);
        let pc = net.pc;
        // SAFETY: tile `ti` alone writes its gradient slice within a
        // dispatch; the serial path runs exactly one inline tile, and
        // consecutive dispatches are joined, so no two returned views
        // are ever written concurrently.
        let grad_of = move |ti: usize| -> &'static mut [f32] {
            unsafe {
                if par {
                    gb_p.rows_mut(pc, ti..ti + 1)
                } else {
                    g_p.rows_mut(pc, 0..1)
                }
            }
        };

        // Decoder backward → dW1/b1/w2/b2 and dz into dh_buf. Rows i,
        // bs+i and 2bs+i all derive from this tile's i, so the dh_buf
        // row sets of different tiles stay disjoint.
        {
            let hs: &[f32] = &h;
            let hp_v: &[f32] = &hid_p;
            let hn_v: &[f32] = &hid_n;
            let sp_v: &[f32] = &s_p;
            let sn_v: &[f32] = &s_n;
            let dh_p = SendPtr::of(&mut dh_buf);
            exec.for_tiles(bs, |ti, irange| {
                let gt = grad_of(ti);
                let mut dhid = pool.take(dd);
                let mut din = pool.take(2 * dh);
                let mut ddin = pool.take(2 * dh);
                for i in irange {
                    let wi = edge_mask[i];
                    if wi <= 0.0 {
                        continue;
                    }
                    for pass in 0..2 {
                        let (sg, hid, b_row) = if pass == 0 {
                            (-sigmoid(-sp_v[i]) * wi / wnorm, &hp_v[i * dd..(i + 1) * dd], bs + i)
                        } else {
                            (sigmoid(sn_v[i]) * wi / wnorm, &hn_v[i * dd..(i + 1) * dd], 2 * bs + i)
                        };
                        gt[lo.b2] += sg;
                        for k in 0..dd {
                            gt[lo.w2 + k] += sg * hid[k];
                            dhid[k] = if hid[k] > 0.0 { sg * p[lo.w2 + k] } else { 0.0 };
                        }
                        din[..dh].copy_from_slice(&hs[i * dh..(i + 1) * dh]);
                        din[dh..2 * dh].copy_from_slice(&hs[b_row * dh..(b_row + 1) * dh]);
                        vadd(&mut gt[lo.b1..lo.b1 + dd], &dhid[..dd]);
                        outer_acc(
                            &mut gt[lo.w1..lo.w1 + dd * 2 * dh],
                            &dhid[..dd],
                            &din[..2 * dh],
                        );
                        ddin[..2 * dh].fill(0.0);
                        matvec_t_acc(
                            &p[lo.w1..lo.w1 + dd * 2 * dh],
                            &dhid[..dd],
                            &mut ddin[..2 * dh],
                        );
                        // SAFETY: rows i / b_row belong to this tile.
                        let d_i = unsafe { dh_p.rows_mut(dh, i..i + 1) };
                        vadd(d_i, &ddin[..dh]);
                        let d_b = unsafe { dh_p.rows_mut(dh, b_row..b_row + 1) };
                        vadd(d_b, &ddin[dh..2 * dh]);
                    }
                }
            });
        }

        // Attention backward, shallowest hop first (children receive
        // their dh before their own level is processed — the `for_tiles`
        // join between levels is the ordering barrier). Within a level,
        // tiles own disjoint target rows and therefore disjoint child
        // slot rows; per-target math is the serial code verbatim on the
        // tile's own gradient slice.
        {
            let hs: &[f32] = &h;
            let xs: &[f32] = &x;
            let asums: &[f32] = &asum;
            let att_as: &[f32] = &att_a;
            let att_ks: &[f32] = &att_k;
            let att_vs: &[f32] = &att_v;
            let dh_p = SendPtr::of(&mut dh_buf);
            let dx_p = SendPtr::of(&mut dx_buf);
            for lev in 0..hops {
                let dt_in = inputs[net.i_hop_dt[lev]].as_f32()?;
                let mask_in = inputs[net.i_hop_mask[lev]].as_f32()?;
                let ef_in = inputs[net.i_hop_efeat[lev]].as_f32()?;
                let child_base = net.lvl_off[lev + 1];
                let gbase = child_base - roots;
                let lbase = net.lvl_off[lev];
                exec.for_tiles(net.lvl_size[lev], |ti, targets| {
                    let gt = grad_of(ti);
                    let mut ds = pool.take(dh);
                    let mut da = pool.take(dh);
                    let mut dqr = pool.take(dh);
                    let mut dk = pool.take(dh);
                    let mut dv_ = pool.take(dh);
                    let mut dalpha = pool.take(fanout);
                    let mut dkin = pool.take(ki);
                    let mut kin = pool.take(ki);
                    let mut qr = pool.take(dh);
                    for r0 in targets {
                        let root_row = lbase + r0;
                        let hr = &hs[root_row * dh..(root_row + 1) * dh];
                        // SAFETY: this tile owns target row `root_row` of
                        // dh_buf/dx_buf and its child slot rows; target
                        // reads never overlap another tile's child writes
                        // (child_base lies past every target row of this
                        // level).
                        let d_tgt = unsafe { dh_p.rows(dh, root_row..root_row + 1) };
                        let mut nz = false;
                        for k in 0..dh {
                            let dval = d_tgt[k];
                            // lint: allow(float-eq, "exact-zero gradient skip; any nonzero must propagate")
                            if dval != 0.0 {
                                nz = true;
                            }
                            ds[k] = dval * (1.0 - hr[k] * hr[k]);
                        }
                        if !nz {
                            continue;
                        }
                        let xr = &xs[root_row * dh..(root_row + 1) * dh];
                        let ao = root_row * dh;
                        let dx_r = unsafe { dx_p.rows_mut(dh, root_row..root_row + 1) };
                        vadd(&mut gt[lo.b_o..lo.b_o + dh], &ds[..dh]);
                        outer_acc(&mut gt[lo.w_s..lo.w_s + dh * dh], &ds[..dh], xr);
                        matvec_t_acc(&p[lo.w_s..lo.w_s + dh * dh], &ds[..dh], &mut dx_r[..dh]);
                        outer_acc(
                            &mut gt[lo.w_a..lo.w_a + dh * dh],
                            &ds[..dh],
                            &asums[ao..ao + dh],
                        );
                        da[..dh].fill(0.0);
                        matvec_t_acc(&p[lo.w_a..lo.w_a + dh * dh], &ds[..dh], &mut da[..dh]);
                        // Softmax backward over the valid slots.
                        let mut adot = 0.0f32;
                        for j in 0..fanout {
                            let slot = r0 * fanout + j;
                            if mask_in[slot] <= 0.5 {
                                continue;
                            }
                            dalpha[j] = dot(
                                &da[..dh],
                                &att_vs[(gbase + slot) * dh..(gbase + slot + 1) * dh],
                            );
                            adot += att_as[gbase + slot] * dalpha[j];
                        }
                        matvec(&p[lo.w_q..lo.w_q + dh * dh], xr, &mut qr[..dh]);
                        dqr[..dh].fill(0.0);
                        for j in 0..fanout {
                            let slot = r0 * fanout + j;
                            if mask_in[slot] <= 0.5 {
                                continue;
                            }
                            let gs = gbase + slot;
                            let a = att_as[gs];
                            let de_j = a * (dalpha[j] - adot);
                            axpy(&mut dqr[..dh], de_j * scale_inv, &att_ks[gs * dh..(gs + 1) * dh]);
                            for k in 0..dh {
                                dk[k] = de_j * qr[k] * scale_inv;
                                dv_[k] = a * da[k];
                            }
                            let cr = child_base + slot;
                            let crow = cr * dh;
                            kin[..dh].copy_from_slice(&hs[crow..crow + dh]);
                            time_enc(dt_in[slot], dt_scale, &mut kin[dh..dh + dte]);
                            kin[dh + dte..ki].copy_from_slice(&ef_in[slot * de..(slot + 1) * de]);
                            outer_acc(&mut gt[lo.w_k..lo.w_k + dh * ki], &dk[..dh], &kin[..ki]);
                            outer_acc(&mut gt[lo.w_v..lo.w_v + dh * ki], &dv_[..dh], &kin[..ki]);
                            dkin[..ki].fill(0.0);
                            matvec_t_acc(&p[lo.w_k..lo.w_k + dh * ki], &dk[..dh], &mut dkin[..ki]);
                            matvec_t_acc(&p[lo.w_v..lo.w_v + dh * ki], &dv_[..dh], &mut dkin[..ki]);
                            // SAFETY: child slot rows derive from this
                            // tile's target rows alone.
                            let d_child = unsafe { dh_p.rows_mut(dh, cr..cr + 1) };
                            vadd(d_child, &dkin[..dh]);
                        }
                        outer_acc(&mut gt[lo.w_q..lo.w_q + dh * dh], &dqr[..dh], xr);
                        matvec_t_acc(&p[lo.w_q..lo.w_q + dh * dh], &dqr[..dh], &mut dx_r[..dh]);
                    }
                });
            }
        }
        // Leaf nodes: h = x, so their dh flows straight into dx
        // (element-wise, so any tile split is bitwise-identical).
        {
            let dhs: &[f32] = &dh_buf;
            let dx_p = SendPtr::of(&mut dx_buf);
            exec.for_tiles(n - inner, |_ti, rrange| {
                let (lo_row, hi_row) = (inner + rrange.start, inner + rrange.end);
                // SAFETY: leaf rows [lo_row, hi_row) belong to this tile.
                let dst = unsafe { dx_p.rows_mut(dh, lo_row..hi_row) };
                vadd(dst, &dhs[lo_row * dh..hi_row * dh]);
            });
        }

        // Projection backward (and through it, the GRU), batch-tiled in
        // TILE_ROWS blocks. The W_in gradient and the dm̃ transpose pass
        // go through the blocked kernels (whose ascending-tile-row,
        // zero-skipping order is the exact per-row sequence — rows with
        // an all-zero upstream gradient contribute only exact-zero
        // elements, which both kernels skip); b_in and the GRU chain keep
        // the per-row skip gates via `nzrow`, computed from the upstream
        // dx values exactly as the serial code's `nz` flag was.
        {
            let dxs: &[f32] = &dx_buf;
            let xs: &[f32] = &x;
            let mts: &[f32] = &mt;
            let g_rs: &[f32] = &g_r;
            let g_zs: &[f32] = &g_z;
            let g_cs: &[f32] = &g_c;
            exec.for_tiles(n, |ti, rows| {
                let gt = grad_of(ti);
                let mut dupre_t = pool.take(TILE_ROWS * dh);
                let mut u_t = pool.take(TILE_ROWS * ui);
                let mut dufull_t = pool.take(TILE_ROWS * ui);
                let mut gin = pool.take(gi);
                let mut rh = pool.take(dm);
                let mut dcpre = pool.take(dm);
                let mut dzpre = pool.take(dm);
                let mut drh = pool.take(dm);
                let mut drpre = pool.take(dm);
                let mut b0 = rows.start;
                while b0 < rows.end {
                    let b1 = (b0 + TILE_ROWS).min(rows.end);
                    let t = b1 - b0;
                    let mut nzrow = [false; TILE_ROWS];
                    for (i, row) in (b0..b1).enumerate() {
                        let xo = row * dh;
                        let mut nz = false;
                        for k in 0..dh {
                            let dval = dxs[xo + k];
                            // lint: allow(float-eq, "exact-zero gradient skip; any nonzero must propagate")
                            if dval != 0.0 {
                                nz = true;
                            }
                            dupre_t[i * dh + k] = dval * (1.0 - xs[xo + k] * xs[xo + k]);
                        }
                        nzrow[i] = nz;
                        let uo = i * ui;
                        if net.use_memory {
                            u_t[uo..uo + dm].copy_from_slice(&mts[row * dm..(row + 1) * dm]);
                            u_t[uo + dm..uo + dm + dv]
                                .copy_from_slice(&node_feat[row * dv..(row + 1) * dv]);
                            time_enc(mem_dt[row], dt_scale, &mut u_t[uo + dm + dv..uo + ui]);
                        } else {
                            u_t[uo..uo + dv].copy_from_slice(&node_feat[row * dv..(row + 1) * dv]);
                        }
                    }
                    for i in 0..t {
                        if nzrow[i] {
                            vadd(&mut gt[lo.b_in..lo.b_in + dh], &dupre_t[i * dh..(i + 1) * dh]);
                        }
                    }
                    outer_acc_block(&mut gt[lo.w_in..lo.w_in + dh * ui], &dupre_t, &u_t, t, dh, ui);
                    if net.use_memory {
                        // dm̃ for the whole block in one transpose pass
                        // (the buffer is recycled across blocks — clear
                        // the accumulator region first).
                        dufull_t[..t * ui].fill(0.0);
                        let w_in = &p[lo.w_in..lo.w_in + dh * ui];
                        gemm_t_acc(w_in, &dupre_t, t, dh, ui, &mut dufull_t);
                        for (i, row) in (b0..b1).enumerate() {
                            if !nzrow[i] {
                                continue;
                            }
                            let mk = mail_mask[row];
                            // lint: allow(float-eq, "mask is an exact 0.0/1.0 sentinel written by the sampler")
                            if mk == 0.0 {
                                continue;
                            }
                            // GRU backward with dgru = mk · dm̃.
                            let dufull = &dufull_t[i * ui..i * ui + ui];
                            let o = row * dm;
                            let mem_i = &mem[o..o + dm];
                            gin[..maild].copy_from_slice(&mail[row * maild..(row + 1) * maild]);
                            time_enc(mail_dt[row], dt_scale, &mut gin[maild..gi]);
                            for k in 0..dm {
                                let dg = mk * dufull[k];
                                let (r, z, c) = (g_rs[o + k], g_zs[o + k], g_cs[o + k]);
                                dcpre[k] = dg * (1.0 - z) * (1.0 - c * c);
                                dzpre[k] = dg * (mem_i[k] - c) * z * (1.0 - z);
                                rh[k] = r * mem_i[k];
                            }
                            vadd(&mut gt[lo.b_n..lo.b_n + dm], &dcpre[..dm]);
                            vadd(&mut gt[lo.b_z..lo.b_z + dm], &dzpre[..dm]);
                            outer_acc(&mut gt[lo.w_n..lo.w_n + dm * gi], &dcpre[..dm], &gin[..gi]);
                            outer_acc(&mut gt[lo.u_n..lo.u_n + dm * dm], &dcpre[..dm], &rh[..dm]);
                            outer_acc(&mut gt[lo.w_z..lo.w_z + dm * gi], &dzpre[..dm], &gin[..gi]);
                            outer_acc(&mut gt[lo.u_z..lo.u_z + dm * dm], &dzpre[..dm], mem_i);
                            drh[..dm].fill(0.0);
                            let u_n = &p[lo.u_n..lo.u_n + dm * dm];
                            matvec_t_acc(u_n, &dcpre[..dm], &mut drh[..dm]);
                            for k in 0..dm {
                                let r = g_rs[o + k];
                                drpre[k] = drh[k] * mem_i[k] * r * (1.0 - r);
                            }
                            vadd(&mut gt[lo.b_r..lo.b_r + dm], &drpre[..dm]);
                            outer_acc(&mut gt[lo.w_r..lo.w_r + dm * gi], &drpre[..dm], &gin[..gi]);
                            outer_acc(&mut gt[lo.u_r..lo.u_r + dm * dm], &drpre[..dm], mem_i);
                        }
                    }
                    b0 = b1;
                }
            });
        }

        // Reduce per-tile gradients into `g` in fixed tile order: a given
        // tile count is run-to-run deterministic (the serial path wrote
        // `g` directly and skips this entirely).
        if par {
            for ti in 0..exec.tiles {
                vadd(&mut g, &gbufs[ti * pc..(ti + 1) * pc]);
            }
        }

        // Adam is element-wise, so splitting the parameter vector across
        // tiles is bitwise-identical to the serial sweep.
        let mut np = pool.take(net.pc);
        let mut nm = pool.take(net.pc);
        let mut nv = pool.take(net.pc);
        {
            let gs: &[f32] = &g;
            let np_p = SendPtr::of(&mut np);
            let nm_p = SendPtr::of(&mut nm);
            let nv_p = SendPtr::of(&mut nv);
            exec.for_tiles(net.pc, |_ti, krange| {
                // SAFETY: parameter range `krange` belongs to this tile.
                let (np_r, nm_r, nv_r) = unsafe {
                    (
                        np_p.rows_mut(1, krange.clone()),
                        nm_p.rows_mut(1, krange.clone()),
                        nv_p.rows_mut(1, krange.clone()),
                    )
                };
                adam(
                    &p[krange.clone()],
                    &adam_m[krange.clone()],
                    &adam_v[krange.clone()],
                    &gs[krange],
                    lr,
                    step,
                    np_r,
                    nm_r,
                    nv_r,
                );
            });
        }
        new_p = Some(np);
        new_m = Some(nm);
        new_v = Some(nv);
    }

    // ---- Refreshed memory + partner messages for the batch roots.
    let (mut nmem, mut nmail) = (None, None);
    if net.use_memory {
        let mut bmem = pool.take(2 * bs * dm);
        bmem.copy_from_slice(&mt[..2 * bs * dm]);
        let mut bmail = pool.take(2 * bs * maild);
        for i in 0..bs {
            for k in 0..maild {
                let ef = if k < de { batch_efeat[i * de + k] } else { 0.0 };
                let from_dst = if k < dm { mt[(bs + i) * dm + k] } else { 0.0 };
                let from_src = if k < dm { mt[i * dm + k] } else { 0.0 };
                bmail[i * maild + k] = from_dst + ef;
                bmail[(bs + i) * maild + k] = from_src + ef;
            }
        }
        nmem = Some(bmem);
        nmail = Some(bmail);
    }

    // ---- Emit outputs in manifest order.
    let (mut s_p, mut s_n) = (Some(s_p), Some(s_n));
    let mut emb_done = false;
    for os in &spec.outputs {
        let buf = match os.name.as_str() {
            "loss" => {
                let mut b = pool.take(1);
                b[0] = loss;
                b
            }
            "new_params" => opt_buf(&mut new_p, "new_params")?,
            "new_adam_m" => opt_buf(&mut new_m, "new_adam_m")?,
            "new_adam_v" => opt_buf(&mut new_v, "new_adam_v")?,
            "pos_score" => opt_buf(&mut s_p, "pos_score")?,
            "neg_score" => opt_buf(&mut s_n, "neg_score")?,
            "emb" => {
                ensure!(!emb_done, "duplicate `emb` output");
                emb_done = true;
                let mut b = pool.take(bs * dh);
                b.copy_from_slice(&h[..bs * dh]);
                b
            }
            "new_mem" => opt_buf(&mut nmem, "new_mem")?,
            "new_mail" => opt_buf(&mut nmail, "new_mail")?,
            other => bail!("reference nn: unknown output `{other}`"),
        };
        out.push(Tensor::f32_pooled(&os.shape, buf)?);
    }
    Ok(())
}

fn opt_buf(slot: &mut Option<PoolBuf>, name: &str) -> Result<PoolBuf> {
    slot.take().ok_or_else(|| {
        anyhow::anyhow!("reference nn: output `{name}` not available for this step kind")
    })
}

// ---------------------------------------------------------------------
// Node-classification step
// ---------------------------------------------------------------------

/// Execute the `clf` step: softmax/cross-entropy MLP on harvested
/// embeddings with a real Adam update. `lr == 0` runs inference only
/// (`new_*` outputs pass the state through unchanged). Rows whose label
/// is outside `0..classes` are treated as masked out.
pub(crate) fn run_clf_step(
    spec: &StepSpec,
    inputs: &[Tensor],
    out: &mut Vec<Tensor>,
    pool: &TensorPool,
) -> Result<()> {
    let d = NnDims::from_hlo(&spec.hlo)?;
    let ch = d.ch;
    let i_params = spec.input_index("params")?;
    let i_m = spec.input_index("adam_m")?;
    let i_v = spec.input_index("adam_v")?;
    let i_step = spec.input_index("step")?;
    let i_lr = spec.input_index("lr")?;
    let i_emb = spec.input_index("emb")?;
    let i_lab = spec.input_index("labels")?;
    let i_mask = spec.input_index("label_mask")?;

    let p = inputs[i_params].as_f32()?;
    let adam_m = inputs[i_m].as_f32()?;
    let adam_v = inputs[i_v].as_f32()?;
    let step = inputs[i_step].scalar_f32()?;
    let lr = inputs[i_lr].scalar_f32()?;
    let emb = inputs[i_emb].as_f32()?;
    let labels = inputs[i_lab].as_i32()?;
    let label_mask = inputs[i_mask].as_f32()?;

    let emb_spec = &spec.inputs[i_emb];
    ensure!(emb_spec.shape.len() == 2, "clf emb must be rank 2");
    let bs = emb_spec.shape[0];
    let dh = emb_spec.shape[1];
    let logits_spec = spec
        .outputs
        .iter()
        .find(|o| o.name == "logits")
        .ok_or_else(|| anyhow::anyhow!("clf step has no `logits` output"))?;
    ensure!(logits_spec.shape.len() == 2, "clf logits must be rank 2");
    let classes = logits_spec.shape[1];
    ensure!(classes >= 2 && classes <= MAX_CLASSES, "clf classes {classes} unsupported");
    check_dim("dh (clf emb width)", dh)?;
    ensure!(
        dh == d.dh,
        "clf emb width {dh} != configured dh {} (hlo `{}`)",
        d.dh,
        spec.hlo
    );
    let pc = p.len();
    ensure!(
        pc == clf_param_count(&d, classes),
        "clf params has {pc} floats, layout wants {}",
        clf_param_count(&d, classes)
    );
    let mut o = Off(0);
    let w1 = o.take(ch * dh);
    let b1 = o.take(ch);
    let w2 = o.take(classes * ch);
    let b2 = o.take(classes);

    // Forward: hid = relu(W1 e + b1); logits = W2 hid + b2.
    let mut logits = pool.take(bs * classes);
    let mut hid = pool.take(bs * ch);
    for i in 0..bs {
        let e = &emb[i * dh..(i + 1) * dh];
        {
            let hrow = &mut hid[i * ch..(i + 1) * ch];
            matvec(&p[w1..w1 + ch * dh], e, hrow);
            for k in 0..ch {
                hrow[k] = (hrow[k] + p[b1 + k]).max(0.0);
            }
        }
        let hrow = &hid[i * ch..(i + 1) * ch];
        let lrow = &mut logits[i * classes..(i + 1) * classes];
        matvec(&p[w2..w2 + classes * ch], hrow, lrow);
        for c in 0..classes {
            lrow[c] += p[b2 + c];
        }
    }

    // Mean masked cross-entropy (also emitted as `loss` when requested).
    let valid = |i: usize| label_mask[i] > 0.0 && labels[i] >= 0 && (labels[i] as usize) < classes;
    let mut wsum = 0.0f32;
    for i in 0..bs {
        if valid(i) {
            wsum += label_mask[i];
        }
    }
    let wnorm = wsum.max(1e-6);
    let mut probs = pool.take(bs * classes);
    let mut loss_acc = 0.0f64;
    for i in 0..bs {
        if !valid(i) {
            continue;
        }
        let row = &logits[i * classes..(i + 1) * classes];
        let mut mx = f32::MIN;
        for c in 0..classes {
            mx = mx.max(row[c]);
        }
        let mut esum = 0.0f32;
        for c in 0..classes {
            let ex = (row[c] - mx).exp();
            probs[i * classes + c] = ex;
            esum += ex;
        }
        for c in 0..classes {
            probs[i * classes + c] /= esum;
        }
        let y = labels[i] as usize;
        let py = probs[i * classes + y].max(1e-12);
        loss_acc -= (label_mask[i] * py.ln()) as f64 / wnorm as f64;
    }
    let loss = loss_acc as f32;

    // Backward + Adam (skipped for inference calls).
    let (mut np, mut nm, mut nv) = (pool.take(pc), pool.take(pc), pool.take(pc));
    // lint: allow(float-eq, "lr == 0.0 is the exact inference-mode sentinel")
    if lr != 0.0 {
        let mut g = pool.take(pc);
        let mut dlg = pool.take(classes);
        let mut dhid = pool.take(ch);
        for i in 0..bs {
            if !valid(i) {
                continue;
            }
            let wi = label_mask[i] / wnorm;
            let y = labels[i] as usize;
            for c in 0..classes {
                let onehot = if c == y { 1.0 } else { 0.0 };
                dlg[c] = (probs[i * classes + c] - onehot) * wi;
            }
            let hrow = &hid[i * ch..(i + 1) * ch];
            vadd(&mut g[b2..b2 + classes], &dlg[..classes]);
            outer_acc(&mut g[w2..w2 + classes * ch], &dlg[..classes], hrow);
            dhid[..ch].fill(0.0);
            matvec_t_acc(&p[w2..w2 + classes * ch], &dlg[..classes], &mut dhid[..ch]);
            for k in 0..ch {
                if hrow[k] <= 0.0 {
                    dhid[k] = 0.0;
                }
            }
            let e = &emb[i * dh..(i + 1) * dh];
            vadd(&mut g[b1..b1 + ch], &dhid[..ch]);
            outer_acc(&mut g[w1..w1 + ch * dh], &dhid[..ch], e);
        }
        adam(p, adam_m, adam_v, &g, lr, step, &mut np, &mut nm, &mut nv);
    } else {
        np.copy_from_slice(p);
        nm.copy_from_slice(adam_m);
        nv.copy_from_slice(adam_v);
    }

    let (mut np, mut nm, mut nv, mut logits) = (Some(np), Some(nm), Some(nv), Some(logits));
    for os in &spec.outputs {
        let buf = match os.name.as_str() {
            "loss" => {
                let mut b = pool.take(1);
                b[0] = loss;
                b
            }
            "new_params" => opt_buf(&mut np, "new_params")?,
            "new_adam_m" => opt_buf(&mut nm, "new_adam_m")?,
            "new_adam_v" => opt_buf(&mut nv, "new_adam_v")?,
            "logits" => opt_buf(&mut logits, "logits")?,
            other => bail!("reference nn clf: unknown output `{other}`"),
        };
        out.push(Tensor::f32_pooled(&os.shape, buf)?);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{synthetic, synthetic_with_width};
    use crate::runtime::StepSpec;
    use crate::util::rng::Rng;

    /// Deterministic per-input values exercising every code path: binary
    /// masks, non-trivial dt, nonzero mail/memory/features.
    fn fill_input(name: &str, k: usize) -> f32 {
        let i = k as f32;
        match name {
            "params" => 0.0, // overridden by the caller
            "adam_m" | "adam_v" => 0.0,
            "step" => 0.0,
            "lr" => 0.01,
            "dt_scale" => 0.5,
            "edge_mask" => {
                if k < 12 {
                    1.0
                } else {
                    0.0
                }
            }
            n if n.starts_with("mask_") => {
                if k % 3 == 2 {
                    0.0
                } else {
                    1.0
                }
            }
            "mail_mask" => (k % 2) as f32,
            "labels" => (k % 2) as f32,
            n if n.starts_with("dt_") || n == "mail_dt" || n == "mem_dt" => {
                3.0 * (i * 0.11).sin().abs()
            }
            _ => 0.2 * (i * 0.37 + 1.3).sin(),
        }
    }

    fn build_inputs(spec: &StepSpec, params: &[f32]) -> Vec<Tensor> {
        spec.inputs
            .iter()
            .map(|ts| {
                let data: Vec<f32> = if ts.name == "params" {
                    params.to_vec()
                } else {
                    (0..ts.numel()).map(|k| fill_input(&ts.name, k)).collect()
                };
                if ts.name == "labels" {
                    Tensor::i32(&ts.shape, data.iter().map(|&x| x as i32).collect()).unwrap()
                } else {
                    Tensor::f32(&ts.shape, data).unwrap()
                }
            })
            .collect()
    }

    /// Run a train step with zeroed Adam moments at step 0; with m=v=0,
    /// `new_adam_m = (1-β1)·g`, so the analytic gradient is recoverable
    /// from the outputs alone.
    fn loss_and_grad(model: &crate::models::Model, params: &[f32]) -> (f64, Vec<f32>) {
        let spec = model.mf.step("train").unwrap();
        let inputs = build_inputs(spec, params);
        let outs = model.train_exe.run(&inputs).unwrap();
        let loss = outs[spec.output_index("loss").unwrap()].scalar_f32().unwrap() as f64;
        let g: Vec<f32> = outs[spec.output_index("new_adam_m").unwrap()]
            .as_f32()
            .unwrap()
            .iter()
            .map(|&m| m / (1.0 - BETA1))
            .collect();
        (loss, g)
    }

    #[test]
    fn analytic_gradients_match_finite_differences() {
        // Width 8 is the legacy network; width 12 exercises non-default,
        // non-lane-multiple dims through the same pooled-scratch path
        // (width 100 runs in release via rust/tests/width100.rs).
        for (arch, width) in [("tgn", 8), ("tgat", 8), ("tgn", 12)] {
            let model = synthetic_with_width(arch, width).unwrap();
            let base = model.init_params.clone();
            let (_, g) = loss_and_grad(&model, &base);
            assert_eq!(g.len(), base.len());
            let eps = 5e-3f32;
            let stride = 13.max(base.len() / 120);
            let mut checked = 0usize;
            for k in (0..base.len()).step_by(stride) {
                let mut pp = base.clone();
                pp[k] += eps;
                let (lp, _) = loss_and_grad(&model, &pp);
                pp[k] = base[k] - eps;
                let (lm, _) = loss_and_grad(&model, &pp);
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let diff = (fd - g[k]).abs();
                let tol = 0.01 + 0.1 * fd.abs().max(g[k].abs());
                assert!(
                    diff <= tol,
                    "{arch} w{width} param {k}: analytic {} vs finite-diff {fd} (|Δ|={diff})",
                    g[k]
                );
                checked += 1;
            }
            assert!(checked >= 45, "{arch} w{width}: gradcheck covered too few params ({checked})");
            let gnorm: f32 = g.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(gnorm > 1e-4, "{arch} w{width}: gradient must not vanish (|g|={gnorm})");
        }
    }

    #[test]
    fn repeated_steps_on_one_batch_reduce_loss() {
        for arch in ["tgn", "tgat"] {
            let model = synthetic(arch).unwrap();
            let spec = model.mf.step("train").unwrap();
            let i_p = spec.input_index("params").unwrap();
            let i_m = spec.input_index("adam_m").unwrap();
            let i_v = spec.input_index("adam_v").unwrap();
            let i_s = spec.input_index("step").unwrap();
            let o_l = spec.output_index("loss").unwrap();
            let o_p = spec.output_index("new_params").unwrap();
            let o_m = spec.output_index("new_adam_m").unwrap();
            let o_v = spec.output_index("new_adam_v").unwrap();
            let mut inputs = build_inputs(spec, &model.init_params);
            let mut first = f32::NAN;
            let mut last = f32::NAN;
            for it in 0..40 {
                let outs = model.train_exe.run(&inputs).unwrap();
                let loss = outs[o_l].scalar_f32().unwrap();
                assert!(loss.is_finite() && loss > 0.0, "{arch} iter {it}: loss {loss}");
                if it == 0 {
                    first = loss;
                }
                last = loss;
                inputs[i_p] =
                    Tensor::f32(&spec.inputs[i_p].shape, outs[o_p].as_f32().unwrap().to_vec())
                        .unwrap();
                inputs[i_m] =
                    Tensor::f32(&spec.inputs[i_m].shape, outs[o_m].as_f32().unwrap().to_vec())
                        .unwrap();
                inputs[i_v] =
                    Tensor::f32(&spec.inputs[i_v].shape, outs[o_v].as_f32().unwrap().to_vec())
                        .unwrap();
                inputs[i_s] = Tensor::scalar(it as f32 + 1.0);
            }
            assert!(
                last < 0.6 * first,
                "{arch}: 40 Adam steps on one batch must cut the loss (first {first}, last {last})"
            );
        }
    }

    #[test]
    fn clf_gradients_match_finite_differences() {
        let model = synthetic("tgn").unwrap();
        let spec = model.mf.step("clf").unwrap();
        let exe = model.clf_exe.as_ref().unwrap();
        let o_l = spec.output_index("loss").unwrap();
        let o_m = spec.output_index("new_adam_m").unwrap();

        let run = |params: &[f32]| -> (f64, Vec<f32>) {
            let inputs = build_inputs(spec, params);
            let outs = exe.run(&inputs).unwrap();
            let loss = outs[o_l].scalar_f32().unwrap() as f64;
            let g = outs[o_m].as_f32().unwrap().iter().map(|&m| m / (1.0 - BETA1)).collect();
            (loss, g)
        };
        let base = model.init_clf_params.clone();
        let (l0, g) = run(&base);
        assert!(l0.is_finite() && l0 > 0.0);
        let eps = 5e-3f32;
        for k in (0..base.len()).step_by(3) {
            let mut pp = base.clone();
            pp[k] += eps;
            let (lp, _) = run(&pp);
            pp[k] = base[k] - eps;
            let (lm, _) = run(&pp);
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let diff = (fd - g[k]).abs();
            assert!(
                diff <= 0.01 + 0.1 * fd.abs().max(g[k].abs()),
                "clf param {k}: analytic {} vs finite-diff {fd}",
                g[k]
            );
        }

        // lr = 0 must be pure inference: state passes through unchanged.
        let mut inputs = build_inputs(spec, &base);
        let i_lr = spec.input_index("lr").unwrap();
        inputs[i_lr] = Tensor::scalar(0.0);
        let outs = exe.run(&inputs).unwrap();
        assert_eq!(
            outs[spec.output_index("new_params").unwrap()].as_f32().unwrap(),
            base.as_slice(),
            "lr=0 must not move the classifier parameters"
        );
    }

    /// Property test over randomized dims: every `Layout` section starts
    /// exactly where the previous one ends (disjoint + contiguous, no
    /// gaps, no overlap) and `tgnn_param_count` equals the sum of section
    /// sizes — not just at the two compiled widths.
    #[test]
    fn layout_sections_are_contiguous_and_sum_to_param_count() {
        let mut rng = Rng::new(0x1A70);
        for case in 0..250u32 {
            let d = NnDims {
                dh: 1 + rng.below(48),
                dte: 1 + rng.below(8),
                dd: 1 + rng.below(32),
                ch: 1 + rng.below(16),
            };
            let use_memory = case % 2 == 0;
            let dv = 1 + rng.below(16);
            let de = 1 + rng.below(16);
            let (dm, maild) =
                if use_memory { (1 + rng.below(48), 1 + rng.below(24)) } else { (0, 0) };
            let lo = layout(&d, use_memory, dv, de, dm, maild);
            let tag = format!(
                "case {case}: {d:?} mem={use_memory} dv={dv} de={de} dm={dm} maild={maild}"
            );
            assert_eq!(lo.gi, maild + d.dte, "{tag}: gi");
            assert_eq!(lo.ki, d.dh + d.dte + de, "{tag}: ki");
            assert_eq!(
                lo.ui,
                if use_memory { dm + dv + d.dte } else { dv },
                "{tag}: ui"
            );
            let mut sections: Vec<(&str, usize, usize)> = Vec::new();
            if use_memory {
                sections.extend([
                    ("w_r", lo.w_r, dm * lo.gi),
                    ("u_r", lo.u_r, dm * dm),
                    ("b_r", lo.b_r, dm),
                    ("w_z", lo.w_z, dm * lo.gi),
                    ("u_z", lo.u_z, dm * dm),
                    ("b_z", lo.b_z, dm),
                    ("w_n", lo.w_n, dm * lo.gi),
                    ("u_n", lo.u_n, dm * dm),
                    ("b_n", lo.b_n, dm),
                ]);
            }
            sections.extend([
                ("w_in", lo.w_in, d.dh * lo.ui),
                ("b_in", lo.b_in, d.dh),
                ("w_q", lo.w_q, d.dh * d.dh),
                ("w_k", lo.w_k, d.dh * lo.ki),
                ("w_v", lo.w_v, d.dh * lo.ki),
                ("w_s", lo.w_s, d.dh * d.dh),
                ("w_a", lo.w_a, d.dh * d.dh),
                ("b_o", lo.b_o, d.dh),
                ("w1", lo.w1, d.dd * 2 * d.dh),
                ("b1", lo.b1, d.dd),
                ("w2", lo.w2, d.dd),
                ("b2", lo.b2, 1),
            ]);
            let mut cursor = 0usize;
            for (name, off, len) in &sections {
                assert_eq!(*off, cursor, "{tag}: section `{name}` must start at {cursor}");
                cursor += len;
            }
            assert_eq!(cursor, lo.total, "{tag}: sections must cover the whole vector");
            assert_eq!(
                tgnn_param_count(&d, use_memory, dv, de, dm, maild),
                cursor,
                "{tag}: tgnn_param_count"
            );
            let classes = 2 + rng.below(32);
            assert_eq!(
                clf_param_count(&d, classes),
                d.ch * d.dh + d.ch + classes * d.ch + classes,
                "{tag}: clf_param_count ({classes} classes)"
            );
        }
    }

    /// Dims beyond `MAX_DIM` must surface as a typed, named error — not a
    /// panic deep inside a producer thread.
    #[test]
    fn dims_over_the_scratch_cap_return_a_named_error() {
        let err = NnDims::from_hlo("reference://syn_tgn/train?dh=999999").unwrap_err();
        let cap = err.downcast_ref::<DimCapError>().expect("typed DimCapError root");
        assert_eq!(cap.what, "dh");
        assert_eq!(cap.dim, 999_999);
        assert_eq!(cap.cap, MAX_DIM);
        assert!(cap.to_string().contains("`dh`"), "error must name the dim: {cap}");

        // A width under the cap parses fine and round-trips the values.
        let d = NnDims::from_hlo("reference://syn_tgn/train?dh=100&dte=4&dd=100&ch=8").unwrap();
        assert_eq!(d, NnDims { dh: 100, dte: 4, dd: 100, ch: 8 });
        // No query at all means the legacy defaults.
        assert_eq!(NnDims::from_hlo("reference://syn_tgn/train").unwrap(), NnDims::default());
        // Unknown keys are rejected (typo-safety for the dims channel).
        assert!(NnDims::from_hlo("reference://syn_tgn/train?dq=9").is_err());
    }
}
