//! Host-side dense tensors exchanged with PJRT executables.
//!
//! The coordinator assembles MFG (message-flow-graph) inputs as plain
//! row-major `f32`/`i32` buffers; this type carries them together with a
//! shape so [`super::Engine`] can marshal them into XLA literals.
//!
//! Storage comes in three modes (the owned / pooled / aliased contract,
//! documented in [`crate::util::tensor_pool`]): owned `Vec`s for one-shot
//! callers, pool-recycled buffers ([`PoolBuf`]) for the steady-state
//! prepare path, and `Arc`-aliased views ([`SharedVec`]) for the
//! per-step-constant `params` / `adam_m` / `adam_v` vectors, which are
//! shared with the executable instead of cloned. Shapes are stored inline
//! (rank ≤ [`MAX_RANK`]) so constructing a tensor never allocates for the
//! shape either.

// lint: allow-file(index, "strides come from the shape, whose numel is validated against the data length")

use crate::util::tensor_pool::{PoolBuf, PoolBufI32};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Maximum tensor rank the inline [`Shape`] supports. The TGL step
/// functions exchange at most rank-3 tensors (`[roots, fanout, de]`); 4
/// leaves headroom.
pub const MAX_RANK: usize = 4;

/// Inline, allocation-free tensor shape (row-major dims, rank ≤
/// [`MAX_RANK`]). Derefs to `&[usize]` so existing slice-based callers
/// keep working.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    dims: [usize; MAX_RANK],
    rank: u8,
}

impl Shape {
    pub fn new(dims: &[usize]) -> Result<Shape> {
        if dims.len() > MAX_RANK {
            bail!("tensor rank {} exceeds MAX_RANK {MAX_RANK}", dims.len());
        }
        let mut s = Shape { dims: [0; MAX_RANK], rank: dims.len() as u8 };
        s.dims[..dims.len()].copy_from_slice(dims);
        Ok(s)
    }

    pub fn rank(&self) -> usize {
        self.rank as usize
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.dims[..self.rank as usize]
    }

    /// Product of the dims (1 for rank 0).
    pub fn numel(&self) -> usize {
        self.as_slice().iter().product()
    }
}

impl std::ops::Deref for Shape {
    type Target = [usize];

    fn deref(&self) -> &[usize] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl PartialEq<[usize]> for Shape {
    fn eq(&self, other: &[usize]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<Vec<usize>> for Shape {
    fn eq(&self, other: &Vec<usize>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// Element type of a [`Tensor`]. Only the two types the TGL step functions
/// exchange: features/state/masks are `F32`, class labels are `I32`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        4
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
}

/// A per-step-constant `f32` vector shared (not copied) into input
/// tensors: the trainer's `params` / `adam_m` / `adam_v`.
///
/// [`SharedVec::arc`] hands out zero-copy aliases for
/// [`Tensor::f32_shared`]; [`SharedVec::copy_from`] writes the step's
/// results back in place via `Arc::make_mut` — allocation-free whenever
/// every alias has been dropped (the JIT-stage contract; see
/// `util::tensor_pool` module docs), and copy-on-write otherwise, so a
/// surviving alias can never observe a torn update.
#[derive(Debug, Clone)]
pub struct SharedVec {
    inner: Arc<Vec<f32>>,
}

impl SharedVec {
    pub fn new(v: Vec<f32>) -> SharedVec {
        SharedVec { inner: Arc::new(v) }
    }

    /// A zero-copy alias of the current contents.
    pub fn arc(&self) -> Arc<Vec<f32>> {
        Arc::clone(&self.inner)
    }

    /// Overwrite the contents in place (no allocation when unaliased and
    /// `src.len()` fits the existing capacity).
    pub fn copy_from(&mut self, src: &[f32]) {
        let v = Arc::make_mut(&mut self.inner);
        v.clear();
        v.extend_from_slice(src);
    }

    /// Replace the contents wholesale (checkpoint restore, sync phases).
    pub fn set(&mut self, v: Vec<f32>) {
        self.inner = Arc::new(v);
    }

    /// Mutable access to the underlying vector (`Arc::make_mut`
    /// semantics).
    pub fn make_mut(&mut self) -> &mut Vec<f32> {
        Arc::make_mut(&mut self.inner)
    }

    pub fn to_vec(&self) -> Vec<f32> {
        self.inner.as_ref().clone()
    }
}

impl From<Vec<f32>> for SharedVec {
    fn from(v: Vec<f32>) -> SharedVec {
        SharedVec::new(v)
    }
}

impl std::ops::Deref for SharedVec {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.inner.as_slice()
    }
}

/// A dense row-major host tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Shape,
    data: Data,
}

#[derive(Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// Pool-recycled storage; returns to its
    /// [`TensorPool`](crate::util::tensor_pool::TensorPool) when the
    /// tensor drops.
    F32Pooled(PoolBuf),
    /// Pool-recycled `i32` storage (label/index buffers of the
    /// node-classification head).
    I32Pooled(PoolBufI32),
    /// Zero-copy alias of a [`SharedVec`] (params / Adam moments).
    F32Shared(Arc<Vec<f32>>),
}

impl Clone for Data {
    fn clone(&self) -> Data {
        match self {
            Data::F32(v) => Data::F32(v.clone()),
            Data::I32(v) => Data::I32(v.clone()),
            // A clone escapes the pool's custody: deep-copy to owned.
            Data::F32Pooled(b) => Data::F32(b.to_vec()),
            Data::I32Pooled(b) => Data::I32(b.to_vec()),
            Data::F32Shared(a) => Data::F32Shared(Arc::clone(a)),
        }
    }
}

impl Tensor {
    /// Build an `f32` tensor; `data.len()` must equal the shape product.
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let shape = Shape::new(shape)?;
        if data.len() != shape.numel() {
            bail!("tensor shape {:?} wants {} elements, got {}", shape, shape.numel(), data.len());
        }
        Ok(Self { shape, data: Data::F32(data) })
    }

    /// Build an `i32` tensor; `data.len()` must equal the shape product.
    pub fn i32(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let shape = Shape::new(shape)?;
        if data.len() != shape.numel() {
            bail!("tensor shape {:?} wants {} elements, got {}", shape, shape.numel(), data.len());
        }
        Ok(Self { shape, data: Data::I32(data) })
    }

    /// Build an `f32` tensor over a pool-recycled buffer (allocation-free
    /// at steady state).
    pub fn f32_pooled(shape: &[usize], buf: PoolBuf) -> Result<Self> {
        let shape = Shape::new(shape)?;
        if buf.len() != shape.numel() {
            bail!("tensor shape {:?} wants {} elements, got {}", shape, shape.numel(), buf.len());
        }
        Ok(Self { shape, data: Data::F32Pooled(buf) })
    }

    /// Build an `i32` tensor over a pool-recycled buffer (allocation-free
    /// at steady state) — the label-buffer path of the clf head.
    pub fn i32_pooled(shape: &[usize], buf: PoolBufI32) -> Result<Self> {
        let shape = Shape::new(shape)?;
        if buf.len() != shape.numel() {
            bail!("tensor shape {:?} wants {} elements, got {}", shape, shape.numel(), buf.len());
        }
        Ok(Self { shape, data: Data::I32Pooled(buf) })
    }

    /// Build an `f32` tensor aliasing shared storage — no copy. The alias
    /// is read-only ([`Self::as_f32_mut`] refuses it).
    pub fn f32_shared(shape: &[usize], data: Arc<Vec<f32>>) -> Result<Self> {
        let shape = Shape::new(shape)?;
        if data.len() != shape.numel() {
            bail!("tensor shape {:?} wants {} elements, got {}", shape, shape.numel(), data.len());
        }
        Ok(Self { shape, data: Data::F32Shared(data) })
    }

    /// All-zero `f32` tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        // lint: allow(panic, "callers pass literal shapes within MAX_RANK")
        let s = Shape::new(shape).expect("shape rank");
        let n = s.numel();
        Self { shape: s, data: Data::F32(vec![0.0; n]) }
    }

    /// A scalar (rank-0) `f32` tensor.
    pub fn scalar(v: f32) -> Self {
        // lint: allow(panic, "the rank-0 shape is always valid")
        Self { shape: Shape::new(&[]).unwrap(), data: Data::F32(vec![v]) }
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            Data::F32(_) | Data::F32Pooled(_) | Data::F32Shared(_) => DType::F32,
            Data::I32(_) | Data::I32Pooled(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::F32Pooled(b) => b.len(),
            Data::I32Pooled(b) => b.len(),
            Data::F32Shared(a) => a.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the storage is a zero-copy alias (shared) rather than
    /// owned/pooled — the "no params copy" assertion hook for tests.
    pub fn is_aliased(&self) -> bool {
        matches!(self.data, Data::F32Shared(_))
    }

    /// Borrow the `f32` payload (errors on dtype mismatch).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::F32Pooled(b) => Ok(b),
            Data::F32Shared(a) => Ok(a.as_slice()),
            Data::I32(_) | Data::I32Pooled(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Mutably borrow the `f32` payload (errors on dtype mismatch or an
    /// aliased tensor, which is read-only by contract).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::F32Pooled(b) => Ok(&mut b[..]),
            Data::F32Shared(_) => bail!("tensor aliases shared storage (read-only)"),
            Data::I32(_) | Data::I32Pooled(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Borrow the `i32` payload (errors on dtype mismatch).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::I32Pooled(b) => Ok(b),
            _ => bail!("tensor is f32, expected i32"),
        }
    }

    /// Consume into the `f32` payload.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            Data::F32(v) => Ok(v),
            Data::F32Pooled(b) => Ok(b.detach()),
            Data::F32Shared(a) => Ok(Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone())),
            Data::I32(_) | Data::I32Pooled(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Raw little-endian bytes of the payload (for literal marshalling).
    pub fn raw_bytes(&self) -> &[u8] {
        fn f32_bytes(v: &[f32]) -> &[u8] {
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
        }
        fn i32_bytes(v: &[i32]) -> &[u8] {
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
        }
        match &self.data {
            Data::F32(v) => f32_bytes(v),
            Data::F32Pooled(b) => f32_bytes(b),
            Data::F32Shared(a) => f32_bytes(a.as_slice()),
            Data::I32(v) => i32_bytes(v),
            Data::I32Pooled(b) => i32_bytes(b),
        }
    }

    /// Scalar extraction: rank-0 or single-element f32 tensor.
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected single-element tensor, got {} elements", v.len());
        }
        Ok(v[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor_pool::TensorPool;

    #[test]
    fn shape_product_enforced() {
        assert!(Tensor::f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(&[4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn shape_is_inline_and_sliceable() {
        let s = Shape::new(&[2, 3, 4]).unwrap();
        assert_eq!(s.rank(), 3);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.as_slice(), &[2, 3, 4]);
        assert_eq!(s[1], 3, "Deref to slice indexing");
        assert_eq!(Shape::new(&[]).unwrap().numel(), 1);
        assert!(Shape::new(&[1; MAX_RANK + 1]).is_err());
    }

    #[test]
    fn zeros_and_scalar() {
        let t = Tensor::zeros(&[3, 2]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(Tensor::scalar(4.25).scalar_f32().unwrap(), 4.25);
    }

    #[test]
    fn raw_bytes_roundtrip() {
        let t = Tensor::f32(&[2], vec![1.0, -2.0]).unwrap();
        let b = t.raw_bytes();
        assert_eq!(b.len(), 8);
        assert_eq!(f32::from_le_bytes(b[0..4].try_into().unwrap()), 1.0);
        assert_eq!(f32::from_le_bytes(b[4..8].try_into().unwrap()), -2.0);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::i32(&[1], vec![7]).unwrap();
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[7]);
    }

    #[test]
    fn pooled_tensor_recycles_on_drop() {
        let pool = TensorPool::new();
        let mut b = pool.take(6);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let t = Tensor::f32_pooled(&[2, 3], b).unwrap();
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        drop(t);
        assert_eq!(pool.free_len(), 1, "dropping a pooled tensor returns the buffer");
    }

    #[test]
    fn pooled_i32_tensor_recycles_and_reads() {
        let pool = TensorPool::new();
        let mut b = pool.take_i32(3);
        b.copy_from_slice(&[7, -1, 3]);
        let t = Tensor::i32_pooled(&[3], b).unwrap();
        assert_eq!(t.dtype(), DType::I32);
        assert_eq!(t.as_i32().unwrap(), &[7, -1, 3]);
        assert!(t.as_f32().is_err());
        let c = t.clone();
        drop(t);
        assert_eq!(pool.free_len_i32(), 1, "dropping a pooled i32 tensor returns the buffer");
        assert_eq!(c.as_i32().unwrap(), &[7, -1, 3], "clone deep-copies to owned");
        assert!(Tensor::i32_pooled(&[4], pool.take_i32(3)).is_err(), "shape product enforced");
    }

    #[test]
    fn pooled_clone_detaches_to_owned() {
        let pool = TensorPool::new();
        let t = Tensor::f32_pooled(&[2], pool.take(2)).unwrap();
        let c = t.clone();
        drop(t);
        assert_eq!(pool.free_len(), 1);
        drop(c);
        assert_eq!(pool.free_len(), 1, "the clone owns its storage");
    }

    #[test]
    fn shared_tensor_aliases_without_copy() {
        let mut sv = SharedVec::new(vec![1.0, 2.0, 3.0]);
        let base_ptr = sv.as_ptr();
        let t = Tensor::f32_shared(&[3], sv.arc()).unwrap();
        assert!(t.is_aliased());
        assert_eq!(t.as_f32().unwrap().as_ptr(), base_ptr, "zero-copy alias");
        // In-place update requires the alias to be gone.
        drop(t);
        sv.copy_from(&[4.0, 5.0, 6.0]);
        assert_eq!(sv.as_ptr(), base_ptr, "unaliased copy_from updates in place");
        assert_eq!(&sv[..], &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn shared_copy_on_write_when_aliased() {
        let mut sv = SharedVec::new(vec![1.0, 2.0]);
        let t = Tensor::f32_shared(&[2], sv.arc()).unwrap();
        sv.copy_from(&[9.0, 9.0]); // alias alive: must not corrupt the reader
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(&sv[..], &[9.0, 9.0]);
    }

    #[test]
    fn shared_tensor_is_read_only() {
        let sv = SharedVec::new(vec![1.0]);
        let mut t = Tensor::f32_shared(&[1], sv.arc()).unwrap();
        assert!(t.as_f32_mut().is_err());
    }
}
