//! Host-side dense tensors exchanged with PJRT executables.
//!
//! The coordinator assembles MFG (message-flow-graph) inputs as plain
//! row-major `f32`/`i32` buffers; this type carries them together with a
//! shape so [`super::Engine`] can marshal them into XLA literals.

use anyhow::{bail, Result};

/// Element type of a [`Tensor`]. Only the two types the TGL step functions
/// exchange: features/state/masks are `F32`, class labels are `I32`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        4
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
}

/// A dense row-major host tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    data: Data,
}

#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    /// Build an `f32` tensor; `data.len()` must equal the shape product.
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("tensor shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Self { shape: shape.to_vec(), data: Data::F32(data) })
    }

    /// Build an `i32` tensor; `data.len()` must equal the shape product.
    pub fn i32(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("tensor shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Self { shape: shape.to_vec(), data: Data::I32(data) })
    }

    /// All-zero `f32` tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: Data::F32(vec![0.0; n]) }
    }

    /// A scalar (rank-0) `f32` tensor.
    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow the `f32` payload (errors on dtype mismatch).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Mutably borrow the `f32` payload (errors on dtype mismatch).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Borrow the `i32` payload (errors on dtype mismatch).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    /// Consume into the `f32` payload.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Raw little-endian bytes of the payload (for literal marshalling).
    pub fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            Data::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
            Data::I32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
        }
    }

    /// Scalar extraction: rank-0 or single-element f32 tensor.
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected single-element tensor, got {} elements", v.len());
        }
        Ok(v[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_enforced() {
        assert!(Tensor::f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(&[4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn zeros_and_scalar() {
        let t = Tensor::zeros(&[3, 2]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(Tensor::scalar(4.25).scalar_f32().unwrap(), 4.25);
    }

    #[test]
    fn raw_bytes_roundtrip() {
        let t = Tensor::f32(&[2], vec![1.0, -2.0]).unwrap();
        let b = t.raw_bytes();
        assert_eq!(b.len(), 8);
        assert_eq!(f32::from_le_bytes(b[0..4].try_into().unwrap()), 1.0);
        assert_eq!(f32::from_le_bytes(b[4..8].try_into().unwrap()), -2.0);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::i32(&[1], vec![7]).unwrap();
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[7]);
    }
}
