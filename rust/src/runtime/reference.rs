//! Deterministic in-process reference backend for step execution.
//!
//! The offline build cannot run AOT artifacts (the PJRT stub has no
//! compiler), which used to leave every training-path property — pipeline
//! determinism, multi-trainer synchronization, allocation-freedom —
//! untestable without `make artifacts`. This backend closes that gap: it
//! executes any [`StepSpec`] as a **pure, deterministic function of its
//! inputs**, with the same dataflow sensitivities as a real TGNN step:
//!
//! - every output folds over *all* inputs (so a stale/missing/reordered
//!   input — the exact bug class pipelining can introduce — changes every
//!   output bit);
//! - `new_params` / `new_adam_m` / `new_adam_v` evolve from their input
//!   counterparts (state advances step to step, like Adam);
//! - `new_mem` / `new_mail` rows evolve from the gathered `mem` / `mail`
//!   inputs (so memory staleness propagates batch to batch, like TGN).
//!
//! It is **not** a numerical emulation of the lowered models — losses do
//! not meaningfully decrease — but bitwise identity across execution
//! modes (sequential / pipelined / multi-worker) is exactly as strong a
//! property here as on real artifacts, because the dependence structure
//! matches.
//!
//! Execution is allocation-free at steady state: outputs are written into
//! buffers recycled through a private [`TensorPool`], which is what lets
//! `rust/tests/alloc_train.rs` assert zero heap allocations across whole
//! train steps *including* engine execution.

use super::manifest::StepSpec;
use super::tensor::{DType, Tensor};
use crate::util::tensor_pool::TensorPool;
use anyhow::{bail, Result};

/// Reference step executor (see module docs). One instance per
/// [`super::Executable`]; owns the output-buffer pool.
#[derive(Debug)]
pub struct RefExec {
    pool: TensorPool,
}

impl RefExec {
    pub fn new() -> RefExec {
        RefExec { pool: TensorPool::new() }
    }

    /// Execute `spec` on `inputs` (already validated against the spec by
    /// the caller), appending one pooled output tensor per output spec.
    pub fn run_into(
        &self,
        spec: &StepSpec,
        inputs: &[Tensor],
        out: &mut Vec<Tensor>,
    ) -> Result<()> {
        // Deterministic fold over every input element, in manifest order.
        // The decay keeps `h` bounded; the per-element weight makes the
        // fold position-sensitive (a permuted input changes `h`).
        let mut h = 0.0f64;
        for t in inputs {
            match t.dtype() {
                DType::F32 => {
                    for &x in t.as_f32()? {
                        h = h * 0.999_991 + x as f64 * 0.618_034;
                    }
                }
                DType::I32 => {
                    for &x in t.as_i32()? {
                        h = h * 0.999_991 + x as f64 * 0.414_214;
                    }
                }
            }
        }
        let hf = (h % 1024.0) as f32;

        for os in &spec.outputs {
            let n = os.numel();
            let mut b = self.pool.take(n);
            match os.name.as_str() {
                "loss" => b[0] = (1.0 / (1.0 + (-h * 1e-3).exp())) as f32,
                "new_params" => {
                    let p = input_f32(spec, inputs, "params")?;
                    let lr = input_f32(spec, inputs, "lr")?[0];
                    ensure_len(n, p.len(), &os.name)?;
                    for (i, (bi, &pi)) in b.iter_mut().zip(p.iter()).enumerate() {
                        *bi = pi - lr * 0.01 * (pi * 1.7 + hf + i as f32 * 0.61).sin();
                    }
                }
                "new_adam_m" => {
                    let m = input_f32(spec, inputs, "adam_m")?;
                    ensure_len(n, m.len(), &os.name)?;
                    for (i, (bi, &mi)) in b.iter_mut().zip(m.iter()).enumerate() {
                        *bi = 0.9 * mi + 0.1 * (hf + i as f32 * 0.37).sin();
                    }
                }
                "new_adam_v" => {
                    let v = input_f32(spec, inputs, "adam_v")?;
                    ensure_len(n, v.len(), &os.name)?;
                    for (i, (bi, &vi)) in b.iter_mut().zip(v.iter()).enumerate() {
                        let g = (hf + i as f32 * 0.37).sin();
                        *bi = 0.999 * vi + 0.001 * g * g;
                    }
                }
                "new_mem" => {
                    // Rows 0..n of the gathered `mem` input are the batch
                    // roots (src | dst | ...), which is what a real step
                    // refreshes and returns.
                    let mem = input_f32(spec, inputs, "mem")?;
                    ensure_min_len(n, mem.len(), &os.name)?;
                    for (i, (bi, &mi)) in b.iter_mut().zip(mem.iter()).enumerate() {
                        *bi = 0.8 * mi + 0.2 * (hf + i as f32 * 0.1).sin();
                    }
                }
                "new_mail" => {
                    let mail = input_f32(spec, inputs, "mail")?;
                    ensure_min_len(n, mail.len(), &os.name)?;
                    for (i, (bi, &mi)) in b.iter_mut().zip(mail.iter()).enumerate() {
                        *bi = 0.8 * mi + 0.2 * (hf + i as f32 * 0.2).cos();
                    }
                }
                "pos_score" => {
                    for (i, bi) in b.iter_mut().enumerate() {
                        *bi = (hf * 1.3 + i as f32 * 0.53).sin();
                    }
                }
                "neg_score" => {
                    for (i, bi) in b.iter_mut().enumerate() {
                        *bi = (hf * 0.7 - i as f32 * 0.71).sin();
                    }
                }
                "logits" => {
                    // Row-sensitive: fold each embedding row separately so
                    // per-example predictions differ.
                    let emb = input_f32(spec, inputs, "emb")?;
                    let rows = os.shape.first().copied().unwrap_or(1).max(1);
                    let classes = n / rows;
                    let de = emb.len() / rows.max(1);
                    for r in 0..rows {
                        let mut e = 0.0f32;
                        for &x in &emb[r * de..(r + 1) * de] {
                            e = e * 0.9 + x;
                        }
                        for c in 0..classes {
                            b[r * classes + c] = (e + hf + c as f32 * 1.3).sin();
                        }
                    }
                }
                // Default: position-coded function of the fold (covers
                // `emb` and any future outputs).
                _ => {
                    for (i, bi) in b.iter_mut().enumerate() {
                        *bi = (hf + i as f32 * 0.29).sin();
                    }
                }
            }
            out.push(Tensor::f32_pooled(&os.shape, b)?);
        }
        Ok(())
    }
}

impl Default for RefExec {
    fn default() -> Self {
        RefExec::new()
    }
}

fn input_f32<'a>(spec: &StepSpec, inputs: &'a [Tensor], name: &str) -> Result<&'a [f32]> {
    let idx = spec.input_index(name)?;
    inputs[idx].as_f32()
}

fn ensure_len(want: usize, have: usize, name: &str) -> Result<()> {
    if want != have {
        bail!("reference step: output `{name}` wants {want} elements, input has {have}");
    }
    Ok(())
}

fn ensure_min_len(want: usize, have: usize, name: &str) -> Result<()> {
    if have < want {
        bail!("reference step: output `{name}` wants ≥{want} elements, input has {have}");
    }
    Ok(())
}
