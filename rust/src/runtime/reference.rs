//! In-process reference backend: a real (tiny) numerical TGNN.
//!
//! The offline build cannot run AOT artifacts (the PJRT stub has no
//! compiler), which used to leave every training-path property untestable
//! without `make artifacts`. This backend closes that gap by executing
//! any synthetic-variant [`StepSpec`] with the genuine model math in
//! [`super::nn`]: sinusoidal time encoding, a GRU memory updater,
//! single-head temporal attention over the sampled neighbors, an MLP
//! link-prediction decoder with BCE loss, hand-derived analytic
//! gradients, and a bias-corrected Adam update (plus a softmax/
//! cross-entropy MLP for the `clf` step).
//!
//! It **is** a numerical emulation of the lowered models now — losses
//! genuinely decrease and eval AP beats chance (`rust/tests/
//! convergence.rs` asserts both artifact-free) — while remaining a pure,
//! deterministic function of its inputs, so bitwise identity across
//! execution modes (sequential / pipelined / multi-worker) is exactly as
//! strong a property here as on real artifacts:
//!
//! - every output depends on every input the modeled step *consumes* —
//!   including all five JIT state gathers (`mem`, `mem_dt`, `mail`,
//!   `mail_dt`, `mail_mask`; memory age feeds the input projection's
//!   time encoding), so a stale/missing/reordered state input — the
//!   exact bug class pipelining can introduce — changes the outputs.
//!   (Eval steps ignore the optimizer moments, exactly as a real eval
//!   step does.);
//! - `new_params` / `new_adam_m` / `new_adam_v` evolve from their input
//!   counterparts via a real gradient step;
//! - `new_mem` / `new_mail` rows evolve from the gathered `mem` / `mail`
//!   inputs (so memory staleness propagates batch to batch, like TGN).
//!
//! Execution is allocation-free at steady state: outputs *and* all
//! forward/backward intermediates are written into buffers recycled
//! through a private [`TensorPool`] — the per-row scratch vectors come
//! from the same pool (a pooled scratch arena, no fixed stack ceiling),
//! which is what lets `rust/tests/alloc_train.rs` assert zero heap
//! allocations across whole train steps *including* engine execution, at
//! production widths (dim 100) as well as the toy default.

use super::manifest::StepSpec;
use super::nn;
use super::tensor::Tensor;
use crate::util::pool::WorkerPool;
use crate::util::tensor_pool::TensorPool;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Reference step executor (see module docs). One instance per
/// [`super::Executable`]; owns the scratch/output buffer pool plus the
/// batch-tile execution state (`set_tiles`).
pub struct RefExec {
    pool: TensorPool,
    /// Batch tiles for the blocked TGNN forward/backward (1 = serial).
    tiles: AtomicUsize,
    /// Lazily-created fork-join pool for tiled execution; sized to the
    /// tile count active at first use (warm-up, not steady state).
    workers: OnceLock<WorkerPool>,
}

impl std::fmt::Debug for RefExec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RefExec").field("tiles", &self.tiles.load(Ordering::Relaxed)).finish()
    }
}

impl RefExec {
    pub fn new() -> RefExec {
        RefExec { pool: TensorPool::new(), tiles: AtomicUsize::new(1), workers: OnceLock::new() }
    }

    /// Set the batch-tile count for TGNN steps (clamped to `1..=`
    /// [`nn::MAX_TILES`]). Tile count 1 runs the serial path inline —
    /// bitwise-identical to the pre-tiling executor; higher counts run
    /// forward/backward tiles on a worker pool with per-tile gradient
    /// buffers reduced in fixed tile order (run-to-run deterministic for
    /// a fixed count, ULP-bounded vs serial). The pool is created with
    /// the tile count active the first time a tiled step runs; a later,
    /// larger setting is capped by that pool's thread count.
    pub fn set_tiles(&self, tiles: usize) {
        self.tiles.store(tiles.clamp(1, nn::MAX_TILES), Ordering::Relaxed);
    }

    fn exec_ctx(&self) -> nn::ExecCtx<'_> {
        let tiles = self.tiles.load(Ordering::Relaxed).clamp(1, nn::MAX_TILES);
        let workers = if tiles > 1 {
            Some(self.workers.get_or_init(|| WorkerPool::new(tiles)))
        } else {
            None
        };
        nn::ExecCtx { tiles, workers }
    }

    /// Execute `spec` on `inputs` (already validated against the spec by
    /// the caller), appending one pooled output tensor per output spec.
    /// The step kind comes from the identity the synthetic builder wrote
    /// into `spec.hlo` (`reference://<variant>/clf` runs the classifier
    /// MLP; train/eval run the TGNN). The URI may carry a dim query
    /// (`?dh=100&...` — see [`nn::NnDims`]), so the step kind is the path
    /// component before any `?`.
    pub fn run_into(
        &self,
        spec: &StepSpec,
        inputs: &[Tensor],
        out: &mut Vec<Tensor>,
    ) -> Result<()> {
        let path = spec.hlo.split('?').next().unwrap_or(&spec.hlo);
        if path.ends_with("/clf") {
            nn::run_clf_step(spec, inputs, out, &self.pool)
        } else {
            nn::run_tgnn_step(spec, inputs, out, &self.pool, &self.exec_ctx())
        }
    }
}

impl Default for RefExec {
    fn default() -> Self {
        RefExec::new()
    }
}
