//! End-to-end **out-of-core** large-scale driver (paper §4.5 + ROADMAP
//! item 2): prove that graph scale is a *disk*-size limit, not a RAM
//! limit.
//!
//! The pipeline never materialises the edge list or the T-CSR in memory:
//!
//! 1. stream a GDELT-shaped chronological edge file to disk
//!    (`datasets::stream_gdelt_like`, O(actors) peak memory);
//! 2. external-sort it into the checksummed per-shard `TGLBIN02` graph
//!    container (`graph::build_container`, bounded by O(|V|) degree
//!    counts plus one shard's slot arrays);
//! 3. run a sampling + node-state epoch over the file: batches are read
//!    straight from the edge stream, neighbors come from a
//!    capacity-bounded [`ShardCache`] over the on-disk container, and
//!    `NodeMemory`/`Mailbox` gathers go through the hot-row cache.
//!
//! The run reports epoch time, throughput, peak RSS, and every cache's
//! hit rate; with the default 100M edges the container is several GB
//! while peak RSS stays bounded by state + one or two resident shards.
//!
//! ```bash
//! cargo run --release --example billion_scale -- \
//!     [--edges 100000000] [--actors 100000] [--shards 8] \
//!     [--cache-shards 2] [--hot-rows 32768] [--batch 4000] \
//!     [--batches 0] [--fanout 10] [--dim 16] [--threads 4] [--dir DIR]
//! ```
//!
//! `--batches N` caps the epoch at N batches (0 = the whole file) so the
//! sampling loop can be smoke-tested without paying a full pass; the
//! generate + container-build phases always cover all `--edges`.

use std::time::Instant;
use tgl::bench::Table;
use tgl::datasets::stream_gdelt_like;
use tgl::graph::{build_container, BuildCfg, EdgeFileReader, EdgeRec, ShardCache};
use tgl::sampler::{Mfg, SamplerConfig, ShardedSampler, Strategy};
use tgl::state::{Mailbox, NodeMemory};
use tgl::util::stats::peak_rss_bytes;

fn arg<T: std::str::FromStr>(args: &[String], key: &str, default: T) -> T {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn gb(bytes: u64) -> String {
    format!("{:.2} GiB", bytes as f64 / (1u64 << 30) as f64)
}

fn rate(hits: u64, misses: u64) -> String {
    let total = hits + misses;
    if total == 0 {
        "-".into()
    } else {
        format!("{:.1}%", 100.0 * hits as f64 / total as f64)
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let edges: u64 = arg(&args, "--edges", 100_000_000);
    let actors: usize = arg(&args, "--actors", 100_000);
    let shards: usize = arg(&args, "--shards", 8);
    let cache_shards: usize = arg(&args, "--cache-shards", 2);
    let hot_rows: usize = arg(&args, "--hot-rows", 32_768);
    let batch: usize = arg(&args, "--batch", 4_000);
    let batches_cap: usize = arg(&args, "--batches", 0);
    let fanout: usize = arg(&args, "--fanout", 10);
    let dim: usize = arg(&args, "--dim", 16);
    let threads: usize = arg(&args, "--threads", 4);
    let dir: String = arg(&args, "--dir", "artifacts/billion_scale".to_string());

    std::fs::create_dir_all(&dir)?;
    let edge_path = std::path::Path::new(&dir).join("stream.edges");
    let container = std::path::Path::new(&dir).join("stream.edges.tcsr");

    let mut table = Table::new(
        "out-of-core billion-scale driver (disk-backed T-CSR + hot-state cache)",
        &["phase", "wall (s)", "throughput", "disk", "peak RSS", "notes"],
    );

    // ── Phase 1: stream the synthetic graph to disk ─────────────────────
    let t0 = Instant::now();
    if EdgeFileReader::open(&edge_path).map(|r| r.num_edges() == edges).unwrap_or(false) {
        println!("[gen] reusing existing {} ({} edges)", edge_path.display(), edges);
    } else {
        stream_gdelt_like(&edge_path, actors, edges, 42)?;
    }
    let gen_s = t0.elapsed().as_secs_f64();
    let edge_bytes = std::fs::metadata(&edge_path)?.len();
    println!(
        "[gen] {} edges / {} actors → {} ({}) in {gen_s:.1}s",
        edges,
        actors,
        edge_path.display(),
        gb(edge_bytes)
    );
    table.row(vec![
        "stream-generate".into(),
        format!("{gen_s:.1}"),
        format!("{:.0} edges/s", edges as f64 / gen_s.max(1e-9)),
        gb(edge_bytes),
        peak_rss_bytes().map(gb).unwrap_or_default(),
        "O(actors) resident".into(),
    ]);

    // ── Phase 2: external-sort into the on-disk shard container ────────
    let t0 = Instant::now();
    let cfg = BuildCfg { shards, ..BuildCfg::default() };
    let disk = build_container(&edge_path, &container, &cfg)?;
    let build_s = t0.elapsed().as_secs_f64();
    let container_bytes = std::fs::metadata(&container)?.len();
    println!(
        "[build] {}-shard container {} ({}) in {build_s:.1}s",
        shards,
        container.display(),
        gb(container_bytes)
    );
    table.row(vec![
        "build-container".into(),
        format!("{build_s:.1}"),
        format!("{:.0} edges/s", edges as f64 / build_s.max(1e-9)),
        gb(container_bytes),
        peak_rss_bytes().map(gb).unwrap_or_default(),
        format!("{shards} shards, chunked external sort"),
    ]);

    // ── Phase 3: out-of-core sampling + state epoch ─────────────────────
    // Batches stream from the edge file; neighbor candidates come from at
    // most `cache_shards` resident shards; memory/mailbox gathers run
    // through the hot-row cache. No model — this is the data-path proof
    // (the learning-identity proof lives in tests/pipeline_identity.rs).
    let cache = ShardCache::new(disk, cache_shards.max(1));
    let sampler = ShardedSampler::on_disk_shared(
        &cache,
        SamplerConfig::uniform_hops(1, fanout, Strategy::MostRecent, threads),
    )?;
    let mut memory = NodeMemory::new(actors, dim);
    memory.enable_hot_cache(hot_rows);
    let mut mailbox = Mailbox::new(actors, 1, dim);
    mailbox.enable_hot_cache(hot_rows);

    let mut reader = EdgeFileReader::open(&edge_path)?;
    let mut chunk: Vec<EdgeRec> = Vec::with_capacity(batch);
    let mut roots: Vec<u32> = Vec::new();
    let mut ts: Vec<f64> = Vec::new();
    let mut mfg = Mfg::new();
    let mut nodes: Vec<(u32, f64, bool)> = Vec::new();
    let mut mem = Vec::new();
    let mut dt = Vec::new();
    let mut mail = Vec::new();
    let mut mail_dt = Vec::new();
    let mut mail_mask = Vec::new();
    let mut update = Vec::new();
    let mut msg = vec![0.0f32; dim];

    let t0 = Instant::now();
    let mut done: u64 = 0;
    let mut nbatch: usize = 0;
    loop {
        let n = reader.read_chunk(&mut chunk, batch)?;
        if n == 0 {
            break;
        }
        roots.clear();
        ts.clear();
        for e in &chunk {
            roots.push(e.src);
            ts.push(e.time);
        }
        for e in &chunk {
            roots.push(e.dst);
            ts.push(e.time);
        }
        sampler.sample_into(&mut mfg, &roots, &ts, nbatch as u64);
        mfg.all_nodes_into(&mut nodes);

        mem.resize(nodes.len() * dim, 0.0);
        dt.resize(nodes.len(), 0.0);
        memory.gather_into(&nodes, &mut mem, &mut dt);
        mail.resize(nodes.len() * dim, 0.0);
        mail_dt.resize(nodes.len(), 0.0);
        mail_mask.resize(nodes.len(), 0.0);
        mailbox.gather_into(&nodes, &mut mail, &mut mail_dt, &mut mail_mask);

        // Cheap deterministic memory update standing in for the AOT step:
        // blend the old row with the staleness signal, then write back.
        update.resize(roots.len() * dim, 0.0);
        for (i, _) in roots.iter().enumerate() {
            let old = &mem[i * dim..(i + 1) * dim];
            let row = &mut update[i * dim..(i + 1) * dim];
            for d in 0..dim {
                row[d] = 0.9 * old[d] + 0.1 * (dt[i] + d as f32);
            }
        }
        memory.scatter(&roots, &ts, &update);
        for (i, e) in chunk.iter().enumerate() {
            let row = &update[i * dim..(i + 1) * dim];
            msg.copy_from_slice(row);
            mailbox.write(e.dst, e.time, &msg);
        }

        done += n as u64;
        nbatch += 1;
        if nbatch % 1000 == 0 {
            println!(
                "[epoch] batch {nbatch}: {done}/{edges} edges, {:.0} edges/s",
                done as f64 / t0.elapsed().as_secs_f64()
            );
        }
        if batches_cap > 0 && nbatch >= batches_cap {
            break;
        }
    }
    let epoch_s = t0.elapsed().as_secs_f64();
    let rss = peak_rss_bytes();

    let gstats = sampler.cache_stats().unwrap_or_default();
    let mstats = memory.hot_stats().unwrap_or_default();
    let bstats = mailbox.hot_stats().unwrap_or_default();
    println!(
        "[epoch] {done} edges in {nbatch} batches, {epoch_s:.1}s ({:.0} edges/s)",
        done as f64 / epoch_s.max(1e-9)
    );
    println!(
        "[cache] graph shards: {} hits / {} misses / {} evictions ({})",
        gstats.hits,
        gstats.misses,
        gstats.evictions,
        rate(gstats.hits, gstats.misses)
    );
    println!(
        "[cache] memory rows: {} ({} evictions); mailbox rows: {} ({} evictions)",
        rate(mstats.hits, mstats.misses),
        mstats.evictions,
        rate(bstats.hits, bstats.misses),
        bstats.evictions
    );
    table.row(vec![
        "out-of-core epoch".into(),
        format!("{epoch_s:.1}"),
        format!("{:.0} edges/s", done as f64 / epoch_s.max(1e-9)),
        gb(edge_bytes + container_bytes),
        rss.map(gb).unwrap_or_default(),
        format!(
            "graph cache {}, hot mem {}, hot mail {}",
            rate(gstats.hits, gstats.misses),
            rate(mstats.hits, mstats.misses),
            rate(bstats.hits, bstats.misses)
        ),
    ]);

    table.print();
    if let Some(rss) = rss {
        let total_disk = edge_bytes + container_bytes;
        println!(
            "\npeak RSS {} vs {} on disk — RSS/disk = {:.2}",
            gb(rss),
            gb(total_disk),
            rss as f64 / total_disk as f64
        );
    }
    table.write_csv("results/billion_scale.csv")?;
    Ok(())
}
