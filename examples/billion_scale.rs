//! End-to-end large-scale driver (paper §4.5): multi-worker training on
//! the GDELT-like and MAG-like billion-edge-class workloads.
//!
//! This is the repository's full-system proof: synthetic GDELT/MAG
//! generators → T-CSR → parallel sampler → shared node memory/mailbox →
//! n data-parallel workers executing the AOT step → synchronized
//! parameters — with measured throughput extrapolated to the paper's full
//! 191M / 1.3B edge counts (the substrate is a CPU PJRT client, so
//! absolute times differ; the per-edge cost and scaling shape are the
//! reproducible quantities).
//!
//! ```bash
//! cargo run --release --example billion_scale -- [--scale 1e-4] [--workers 4]
//! ```

use std::path::Path;
use tgl::bench::Table;
use tgl::coordinator::RunPlan;
use tgl::sched::ChunkScheduler;
use tgl::trainer::MultiTrainer;

fn arg<T: std::str::FromStr>(args: &[String], key: &str, default: T) -> T {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = arg(&args, "--scale", 1e-4);
    let workers: usize = arg(&args, "--workers", 4);
    let epochs: usize = arg(&args, "--epochs", 1);
    let variant = {
        let v: String = arg(&args, "--variant", "tgn_tiny".to_string());
        v
    };

    let mut table = Table::new(
        "billion-scale driver: GDELT-like and MAG-like workloads",
        &["dataset", "|V|", "|E|", "AP(val)", "epoch (s)", "edges/s", "full-size epoch (est.)"],
    );
    for (ds, full_edges) in [("gdelt", 191_290_882f64), ("mag", 1_297_748_926f64)] {
        let plan = RunPlan::new(
            Path::new("artifacts"),
            Path::new("configs"),
            &variant,
            ds,
            scale,
            4,
            42,
        )?;
        println!(
            "[{ds}] generated |V|={} |E|={} (scale {scale:.1e}), {workers} workers",
            plan.graph.num_nodes,
            plan.graph.num_edges()
        );
        let bs = plan.model.dim("bs");
        let (train_end, val_end) = plan.graph.chrono_split(0.70, 0.15);
        let mut trainer = plan.trainer()?;
        let multi = MultiTrainer::new(workers);
        let mut sched = ChunkScheduler::plain(train_end, bs);
        let mut secs = 0.0;
        let mut loss = 0.0;
        for ep in 0..epochs {
            let stats = multi.train_epoch(&mut trainer, &sched.epoch())?;
            println!(
                "[{ds}] epoch {ep}: loss {:.4}, {:.1}s ({:.0} edges/s)",
                stats.mean_loss,
                stats.seconds,
                train_end as f64 / stats.seconds
            );
            secs = stats.seconds;
            loss = stats.mean_loss;
        }
        let val = trainer.eval_range(train_end..val_end)?;
        let eps = train_end as f64 / secs;
        table.row(vec![
            ds.into(),
            plan.graph.num_nodes.to_string(),
            plan.graph.num_edges().to_string(),
            format!("{:.4}", val.ap),
            format!("{secs:.1}"),
            format!("{eps:.0}"),
            format!("{:.1} h", full_edges / eps / 3600.0),
        ]);
        let _ = loss;
    }
    table.print();
    table.write_csv("results/billion_scale.csv")?;
    Ok(())
}
