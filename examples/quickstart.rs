//! Quickstart: train TGN on a Wikipedia-like temporal interaction graph
//! and evaluate link prediction — the 60-second tour of the framework.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::Path;
use tgl::coordinator::RunPlan;

fn main() -> anyhow::Result<()> {
    // 1. Assemble a run plan: compile the AOT artifacts for the `tgn_tiny`
    //    variant (lowered by `make artifacts`), generate a scaled
    //    Wikipedia-like dataset, and build the T-CSR index.
    let plan = RunPlan::new(
        Path::new("artifacts"),
        Path::new("configs"),
        "tgn_tiny",
        "wikipedia",
        0.1, // 10% of the paper's 157k edges
        4,   // sampler threads
        42,  // seed
    )?;
    println!(
        "dataset: |V|={} |E|={} max(t)={:.2e}",
        plan.graph.num_nodes,
        plan.graph.num_edges(),
        plan.graph.max_time()
    );

    // 2. Train for 3 epochs with per-epoch validation AP; test on the
    //    chronological tail (the paper's extrapolation protocol).
    let (report, trainer) = plan.train_link_prediction(3, 1, 1, "wikipedia", true)?;

    // 3. Report.
    println!("\nloss curve:");
    for (ep, loss, secs, val_ap) in &report.epochs {
        println!("  epoch {ep}: loss {loss:.4}  ({secs:.2}s)  val AP {val_ap:.4}");
    }
    println!("\ntest AP {:.4} — runtime breakdown:", report.test_ap);
    for (phase, secs, frac) in trainer.timers.breakdown() {
        println!("  {phase:<10} {secs:>7.2}s {:>5.1}%", frac * 100.0);
    }
    println!("\nNext steps: examples/link_prediction (all variants),");
    println!("            examples/chunk_schedule (Figure 6),");
    println!("            examples/billion_scale (multi-worker GDELT).");
    Ok(())
}
