//! Link prediction across the full model zoo on the four small datasets
//! (paper §4.3, Table 5 / Figure 1 / Figure 5) — the framework's
//! bread-and-butter workflow, with convergence curves written as CSV.
//!
//! ```bash
//! cargo run --release --example link_prediction -- [--full] [--scale 0.1]
//! ```

use std::path::Path;
use tgl::bench::Table;
use tgl::metrics::Curve;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let scale = args
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(0.05);
    let suffix = if full { "" } else { "_tiny" };
    let datasets = ["wikipedia", "reddit", "mooc", "lastfm"];
    let variants = ["jodie", "dysat", "tgat", "tgn", "apan"];
    let epochs = if full { 2 } else { 2 };

    let mut table = Table::new(
        "Table 5: link prediction AP / per-epoch time",
        &["dataset", "variant", "AP", "epoch time (s)"],
    );
    for ds in datasets {
        for base in variants {
            let variant = format!("{base}{suffix}");
            let plan = RunPlanArgs { variant: &variant, dataset: ds, scale }.build()?;
            let (report, _) = plan.train_link_prediction(epochs, 1, 1, ds, false)?;
            println!(
                "[{ds}/{variant}] test AP {:.4}, epoch {:.2}s",
                report.test_ap, report.epoch_seconds
            );
            table.row(vec![
                ds.into(),
                variant.clone(),
                format!("{:.4}", report.test_ap),
                format!("{:.2}", report.epoch_seconds),
            ]);
            // Figure 5-left: validation AP over wall-clock training time.
            if ds == "wikipedia" {
                let mut curve = Curve::default();
                let mut t_acc = 0.0;
                for (_, _, secs, val_ap) in &report.epochs {
                    t_acc += secs;
                    curve.push(t_acc, *val_ap);
                }
                curve.write_csv(
                    Path::new(&format!("results/figure5_convergence_{variant}.csv")),
                    "train_seconds",
                    "val_ap",
                )?;
            }
        }
    }
    table.print();
    table.write_csv("results/table5_all_datasets.csv")?;
    Ok(())
}

/// Small helper so the example reads top-down.
struct RunPlanArgs<'a> {
    variant: &'a str,
    dataset: &'a str,
    scale: f64,
}

impl RunPlanArgs<'_> {
    fn build(&self) -> anyhow::Result<tgl::coordinator::RunPlan> {
        tgl::coordinator::RunPlan::new(
            Path::new("artifacts"),
            Path::new("configs"),
            self.variant,
            self.dataset,
            self.scale,
            8,
            42,
        )
    }
}
