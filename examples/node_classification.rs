//! Dynamic node classification (paper §4.3 / Table 6): the TGNN trained
//! on link prediction is frozen and an MLP head is trained on dynamic
//! node embeddings harvested during a chronological replay.
//!
//! ```bash
//! cargo run --release --example node_classification -- [--full]
//! ```

use std::path::Path;
use tgl::bench::Table;
use tgl::coordinator::RunPlan;
use tgl::trainer::node_classification;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let suffix = if full { "" } else { "_tiny" };
    // Binary AP datasets + the multi-class GDELT-like task (F1-micro).
    let cases = [("wikipedia", 0.1, "AP"), ("reddit", 0.05, "AP"), ("gdelt", 5e-5, "F1-micro")];
    let variants = ["jodie", "dysat", "tgat", "tgn", "apan"];

    let mut table = Table::new(
        "Table 6: dynamic node classification",
        &["dataset", "variant", "metric", "value", "labels (train/test)"],
    );
    for (ds, scale, metric) in cases {
        for base in variants {
            let variant = format!("{base}{suffix}");
            let plan = RunPlan::new(
                Path::new("artifacts"),
                Path::new("configs"),
                &variant,
                ds,
                scale,
                8,
                42,
            )?;
            if plan.graph.labels.is_empty() {
                continue;
            }
            let (report, mut trainer) = plan.train_link_prediction(1, 1, 1, ds, false)?;
            let clf = node_classification(&mut trainer, 0.7, 40, 0.01, 42)?;
            let value = if metric == "AP" { clf.ap } else { clf.f1_micro };
            println!(
                "[{ds}/{variant}] link AP {:.3} -> clf {metric} {:.4}",
                report.test_ap, value
            );
            table.row(vec![
                ds.into(),
                variant.clone(),
                metric.into(),
                format!("{value:.4}"),
                format!("{}/{}", clf.train_labels, clf.test_labels),
            ]);
        }
    }
    table.print();
    table.write_csv("results/table6_nodeclf.csv")?;
    Ok(())
}
