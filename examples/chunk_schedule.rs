//! **Figure 6**: random chunk scheduling at large batch sizes.
//!
//! The paper trains TGN at 8× the tuned batch size (600 → 4800) with 8×
//! the learning rate and shows that without chunking the model stops
//! learning (lost intra-batch dependencies), while 16–32 chunks/batch
//! recovers near-baseline convergence. We reproduce the same protocol at
//! the artifact's compiled sizes: `tgn_tiny` (bs=32, the paper's "600")
//! vs `tgn_big` (bs=256 = 8×, lr×8) with chunks/batch ∈ {1, 8, 16}.
//! Validation loss is computed the paper's way: reset memory, replay the
//! train+val prefix at the small batch size.
//!
//! ```bash
//! cargo run --release --example chunk_schedule -- [--epochs 20]
//! ```

use std::path::Path;
use tgl::coordinator::RunPlan;
use tgl::metrics::Curve;
use tgl::sched::ChunkScheduler;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let epochs: usize = args
        .iter()
        .position(|a| a == "--epochs")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(15);
    let scale = 0.2;

    // The small-batch baseline (600-equivalent) and the 8x configs.
    let cases: &[(&str, usize, f32)] = &[
        ("tgn_tiny", 1, 1.0),  // baseline bs, chunks=1, lr x1
        ("tgn_big", 1, 8.0),   // 8x bs, no chunks    -> should stall
        ("tgn_big", 8, 8.0),   // 8x bs, 8 chunks
        ("tgn_big", 16, 8.0),  // 8x bs, 16 chunks    -> near baseline
    ];

    for &(variant, chunks, lr_mult) in cases {
        let mut plan = RunPlan::new(
            Path::new("artifacts"),
            Path::new("configs"),
            variant,
            "wikipedia",
            scale,
            4,
            42,
        )?;
        plan.options.lr *= lr_mult;
        let bs = plan.model.dim("bs").unwrap();
        let (train_end, val_end) = plan.graph.chrono_split(0.70, 0.15);
        let mut trainer = plan.trainer()?;
        let mut sched = if chunks > 1 {
            ChunkScheduler::new(train_end, bs, bs / chunks, 42)?
        } else {
            ChunkScheduler::plain(train_end, bs)
        };

        let label = format!("{variant}-bs{bs}-c{chunks}");
        let mut curve = Curve::default();
        for ep in 0..epochs {
            let plan_e = sched.epoch();
            trainer.train_epoch(&plan_e)?;
            // Paper protocol: validation loss measured by resetting memory
            // and replaying train+val chronologically at the base bs.
            trainer.reset_chronology();
            trainer.eval_range(0..train_end)?;
            let val = trainer.eval_range(train_end..val_end)?;
            println!("[{label}] epoch {ep}: val loss {:.4} (AP {:.4})", val.mean_loss, val.ap);
            curve.push(ep as f64, val.mean_loss);
        }
        curve
            .moving_average(5)
            .write_csv(
                Path::new(&format!("results/figure6_{label}.csv")),
                "epoch",
                "val_loss_ma5",
            )?;
    }
    println!(
        "\nShape check vs paper Figure 6: the bs-256 / chunks-1 series should sit\n\
         well above the baseline; chunks-8/16 should approach the baseline curve."
    );
    Ok(())
}
