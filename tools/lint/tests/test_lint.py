#!/usr/bin/env python3
"""Tests for pallas-lint: lexer tricky-token corpus, directive parsing,
rule engine on golden fixtures, and the CLI gate's exit codes.

Run from anywhere:  python3 tools/lint/tests/test_lint.py
Stdlib only — this suite must run in the same toolchain-free containers
the linter itself targets.
"""

import io
import os
import subprocess
import sys
import unittest

HERE = os.path.dirname(os.path.abspath(__file__))
LINT_DIR = os.path.dirname(HERE)
sys.path.insert(0, LINT_DIR)

import pallas_lint as pl  # noqa: E402

FIXTURES = os.path.join(HERE, "fixtures")
FIXTURE_CONF = os.path.join(FIXTURES, "lint.conf")


def kinds(src):
    return [(t.kind, t.text) for t in pl.lex(src)]


def sig_kinds(src):
    return [(t.kind, t.text) for t in pl.lex(src) if t.kind not in (pl.WS, pl.COMMENT)]


class TestLexer(unittest.TestCase):
    # -- the tricky-token corpus ------------------------------------

    def test_raw_string_with_hashes(self):
        toks = sig_kinds(r'let s = r#"a "quoted" b"#;')
        texts = [t for k, t in toks if k in (pl.STR, "raw")]
        self.assertEqual(texts, [r'r#"a "quoted" b"#'])

    def test_byte_raw_string_double_hash(self):
        toks = sig_kinds('let s = br##"x "# y"##;')
        texts = [t for k, t in toks if k in (pl.STR, "raw")]
        self.assertEqual(texts, ['br##"x "# y"##'])

    def test_raw_string_swallows_fake_directive(self):
        # a raw string containing comment-looking text must stay one token
        src = 'let s = r#"// lint: allow(panic, "nope")"#;'
        fm = pl.FileModel("<t>", "t.rs", src)
        self.assertEqual(fm.directives, [])

    def test_nested_block_comment(self):
        toks = sig_kinds("/* a /* b */ c */ d")
        self.assertEqual(toks, [(pl.IDENT, "d")])

    def test_unterminated_block_comment_raises(self):
        with self.assertRaises(pl.LexError):
            pl.lex("/* a /* b */ still open")

    def test_lifetime_vs_char_literal(self):
        toks = sig_kinds("fn f<'a>(x: &'a u32) { let c = 'a'; }")
        self.assertIn((pl.LIFETIME, "'a"), toks)
        self.assertIn((pl.CHAR, "'a'"), toks)

    def test_char_escapes(self):
        toks = sig_kinds(r"let c = '\n'; let u = '\u{1F600}'; let b = b'\'';")
        texts = [t for _, t in toks]
        self.assertIn(r"'\n'", texts)
        self.assertIn(r"'\u{1F600}'", texts)
        self.assertIn(r"b'\''", texts)

    def test_string_with_escapes_and_continuation(self):
        src = '"a \\" b \\\n   c"'
        toks = sig_kinds(src)
        self.assertEqual(len(toks), 1)
        self.assertEqual(toks[0][0], pl.STR)

    def test_numeric_literal_kinds(self):
        toks = sig_kinds("1 1.0 1e3 0x1F 2.5f32 3usize 1_000 0b1010")
        got = {text: kind for kind, text in toks}
        self.assertEqual(got["1"], pl.NUM)
        self.assertEqual(got["1.0"], pl.FLOAT)
        self.assertEqual(got["1e3"], pl.FLOAT)
        self.assertEqual(got["0x1F"], pl.NUM)
        self.assertEqual(got["2.5f32"], pl.FLOAT)
        self.assertEqual(got["3usize"], pl.NUM)
        self.assertEqual(got["1_000"], pl.NUM)
        self.assertEqual(got["0b1010"], pl.NUM)

    def test_range_is_not_a_float(self):
        toks = sig_kinds("for i in 0..n {}")
        self.assertIn((pl.PUNCT, ".."), toks)
        self.assertIn((pl.NUM, "0"), toks)

    def test_punct_maximal_munch(self):
        toks = sig_kinds("a ..= b :: c -> d == e <<= f")
        puncts = [t for k, t in toks if k == pl.PUNCT]
        self.assertEqual(puncts, ["..=", "::", "->", "==", "<<="])

    def test_line_and_col_positions(self):
        toks = [t for t in pl.lex("let x = 1;\n    y += 2;") if t.kind == pl.IDENT]
        y = [t for t in toks if t.text == "y"][0]
        self.assertEqual((y.line, y.col), (2, 5))

    def test_attr_span_detection(self):
        fm = pl.FileModel("<t>", "t.rs", "#[derive(Clone)]\npub struct S;\n")
        idx = [i for i, t in enumerate(fm.sig) if t.text == "derive"][0]
        self.assertTrue(fm.in_attr(idx))

    def test_cfg_test_region_detected(self):
        src = (
            "pub fn lib() {}\n"
            "#[cfg(test)]\n"
            "mod tests {\n"
            "    #[test]\n"
            "    fn t() { assert!(true); }\n"
            "}\n"
        )
        fm = pl.FileModel("<t>", "t.rs", src)
        self.assertFalse(fm.in_test(1))
        self.assertTrue(fm.in_test(5))


class TestDirectives(unittest.TestCase):
    def _fm(self, src):
        return pl.FileModel("<t>", "t.rs", src)

    def test_trailing_allow_covers_that_line_only(self):
        fm = self._fm('let x = v[0]; // lint: allow(index, "bounds checked above")\nlet y = v[1];\n')
        d = fm.directives[0]
        self.assertEqual(d.kind, "allow")
        self.assertTrue(d.covers("index", 1))
        self.assertFalse(d.covers("index", 2))

    def test_standalone_allow_covers_next_fn_span(self):
        src = (
            '// lint: allow(panic, "infallible by construction")\n'
            "pub fn f(v: &[u32]) -> u32 {\n"
            "    v[0]\n"
            "}\n"
            "pub fn g() {}\n"
        )
        fm = self._fm(src)
        d = fm.directives[0]
        self.assertEqual(d.scope[0], "span")
        self.assertTrue(d.covers("panic", 3))
        self.assertTrue(d.covers("index", 3))  # panic is the rule class
        self.assertFalse(d.covers("panic", 5))

    def test_allow_file_covers_everything(self):
        fm = self._fm('// lint: allow-file(index, "scanner with guarded offsets")\nfn f() {}\n')
        d = fm.directives[0]
        self.assertEqual(d.scope, ("file",))
        self.assertTrue(d.covers("index", 999))
        self.assertFalse(d.covers("panic", 999))

    def test_deny_alloc_marks_next_fn(self):
        fm = self._fm("// lint: deny(alloc)\npub fn hot() {}\n")
        self.assertTrue(fm.fn_spans[0].deny_alloc)
        self.assertEqual(fm.directives, [])  # deny is not an allow entry

    def test_deny_without_fn_is_malformed(self):
        fm = self._fm("// lint: deny(alloc)\nstruct S;\n")
        self.assertEqual(fm.directives[0].kind, "malformed")

    def test_malformed_directive_flagged(self):
        fm = self._fm("// lint: alow(panic)\nfn f() {}\n")
        self.assertEqual(fm.directives[0].kind, "malformed")

    def test_allow_in_test_region_is_skipped(self):
        src = (
            "#[cfg(test)]\n"
            "mod tests {\n"
            '    // lint: allow(panic, "tests may unwrap")\n'
            "    #[test]\n"
            "    fn t() {}\n"
            "}\n"
        )
        fm = self._fm(src)
        self.assertEqual(fm.directives, [])


class TestRuleEngine(unittest.TestCase):
    """Golden fixtures: each fail/*.rs seeds exactly one rule class."""

    @classmethod
    def setUpClass(cls):
        cls.cfg = pl.parse_config(FIXTURE_CONF)

    def _run(self, *names):
        paths = [os.path.join(FIXTURES, n) for n in names]
        out = io.StringIO()
        code = pl.run(paths, self.cfg, out=out)
        return code, out.getvalue()

    def _assert_fails_with(self, fixture, rule, count):
        code, out = self._run(fixture)
        self.assertEqual(code, 1, out)
        self.assertEqual(out.count(f" {rule}: "), count, out)

    def test_panic_fixture(self):
        code, out = self._run("fail/panic.rs")
        self.assertEqual(code, 1, out)
        self.assertEqual(out.count(" panic: "), 3, out)
        self.assertEqual(out.count(" index: "), 1, out)

    def test_alloc_fixture(self):
        self._assert_fails_with("fail/alloc.rs", "alloc", 2)

    def test_spawn_fixture(self):
        self._assert_fails_with("fail/spawn.rs", "spawn", 1)

    def test_lock_order_fixture(self):
        code, out = self._run("fail/lock_order.rs")
        self.assertEqual(code, 1, out)
        self.assertEqual(out.count(" lock: "), 2, out)
        self.assertIn("lock-order violation", out)
        self.assertIn("not in the declared lock-order table", out)

    def test_float_eq_fixture(self):
        self._assert_fails_with("fail/float_eq.rs", "float-eq", 1)

    def test_cast_fixture(self):
        self._assert_fails_with("fail/cast.rs", "cast", 1)

    def test_crc_fixture(self):
        code, out = self._run("fail/crc.rs")
        self.assertEqual(code, 1, out)
        self.assertEqual(out.count(" crc: "), 2, out)
        self.assertIn("begin_section vs 0 end_section", out)
        self.assertIn("never finish()ed", out)

    def test_clean_fixture_passes(self):
        code, out = self._run("pass/clean.rs")
        self.assertEqual(code, 0, out)
        self.assertNotIn("warning: unused allow", out)

    def test_fail_dir_as_a_whole(self):
        code, out = self._run("fail")
        self.assertEqual(code, 1, out)
        for rule in ("panic", "index", "alloc", "spawn", "lock", "float-eq", "cast", "crc"):
            self.assertIn(f" {rule}: ", out)

    def test_unused_allow_warns(self):
        out = io.StringIO()
        src = '// lint: allow(panic, "stale entry")\npub fn f() {}\n'
        path = os.path.join(FIXTURES, "tmp_unused.rs")
        with open(path, "w", encoding="utf-8") as f:
            f.write(src)
        try:
            code = pl.run([path], self.cfg, out=out)
        finally:
            os.remove(path)
        self.assertEqual(code, 0)
        self.assertIn("warning: unused allow(panic)", out.getvalue())

    def test_allow_without_reason_is_violation(self):
        out = io.StringIO()
        src = "// lint: allow(panic)\npub fn f(v: &[u32]) -> u32 { v[0] }\n"
        path = os.path.join(FIXTURES, "tmp_noreason.rs")
        with open(path, "w", encoding="utf-8") as f:
            f.write(src)
        try:
            code = pl.run([path], self.cfg, out=out)
        finally:
            os.remove(path)
        self.assertEqual(code, 1)
        self.assertIn("without a reason", out.getvalue())

    def test_unknown_rule_in_allow_is_violation(self):
        out = io.StringIO()
        src = '// lint: allow(bogus, "reason")\npub fn f() {}\n'
        path = os.path.join(FIXTURES, "tmp_badrule.rs")
        with open(path, "w", encoding="utf-8") as f:
            f.write(src)
        try:
            code = pl.run([path], self.cfg, out=out)
        finally:
            os.remove(path)
        self.assertEqual(code, 1)
        self.assertIn("unknown rule `bogus`", out.getvalue())

    def test_expect_with_token_argument_not_flagged(self):
        out = io.StringIO()
        src = "pub fn p(s: &mut Scanner) -> R { s.expect(b'{') }\n"
        path = os.path.join(FIXTURES, "tmp_expect.rs")
        with open(path, "w", encoding="utf-8") as f:
            f.write(src)
        try:
            code = pl.run([path], self.cfg, out=out)
        finally:
            os.remove(path)
        self.assertEqual(code, 0, out.getvalue())

    def test_violations_in_cfg_test_are_ignored(self):
        out = io.StringIO()
        src = (
            "#[cfg(test)]\n"
            "mod tests {\n"
            "    #[test]\n"
            '    fn t() { let v = vec![1]; assert_eq!(v[0], 1); panic!("x"); }\n'
            "}\n"
        )
        path = os.path.join(FIXTURES, "tmp_test_region.rs")
        with open(path, "w", encoding="utf-8") as f:
            f.write(src)
        try:
            code = pl.run([path], self.cfg, out=out)
        finally:
            os.remove(path)
        self.assertEqual(code, 0, out.getvalue())


class TestCli(unittest.TestCase):
    """The gate contract scripts/tier1.sh relies on: exit codes 0/1/2."""

    def _cli(self, *args):
        return subprocess.run(
            [sys.executable, os.path.join(LINT_DIR, "pallas_lint.py"), *args],
            capture_output=True,
            text=True,
            cwd=FIXTURES,
        )

    def test_exit_zero_on_clean(self):
        r = self._cli("--config", FIXTURE_CONF, os.path.join(FIXTURES, "pass", "clean.rs"))
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)

    def test_exit_one_on_violations(self):
        r = self._cli("--config", FIXTURE_CONF, os.path.join(FIXTURES, "fail", "panic.rs"))
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)

    def test_exit_two_on_bad_config(self):
        r = self._cli("--config", os.path.join(FIXTURES, "no_such.conf"))
        self.assertEqual(r.returncode, 2)

    def test_exit_two_on_unknown_flag(self):
        r = self._cli("--bogus")
        self.assertEqual(r.returncode, 2)

    def test_repo_tree_is_clean(self):
        # The real gate: the shipped rust/src must lint clean with the
        # shipped config. Failing here means a violation crept in.
        repo = os.path.dirname(os.path.dirname(LINT_DIR))
        r = subprocess.run(
            [sys.executable, os.path.join(LINT_DIR, "pallas_lint.py")],
            capture_output=True,
            text=True,
            cwd=repo,
        )
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)


if __name__ == "__main__":
    unittest.main()  # pass -v for per-test lines
