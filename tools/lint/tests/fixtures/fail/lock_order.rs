//! Seeded lock violations: an acquisition against the declared rank
//! order, and a receiver missing from the `[locks]` table.

use std::sync::Mutex;

pub struct S {
    hot: Mutex<u32>,
    state: Mutex<u32>,
    rogue: Mutex<u32>,
}

impl S {
    pub fn inverted(&self) {
        let a = self.state.lock();
        let b = self.hot.lock();
        drop((a, b));
    }

    pub fn undeclared(&self) {
        let g = self.rogue.lock();
        drop(g);
    }
}
