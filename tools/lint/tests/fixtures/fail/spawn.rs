//! Seeded concurrency violation: raw `thread::spawn` outside the one
//! file named in `[spawn] allow_files`.

pub fn run() {
    let h = std::thread::spawn(|| 1 + 1);
    drop(h);
}
