//! Seeded deny-alloc violations: the annotated fn both grows a Vec and
//! expands `vec![…]`.

// lint: deny(alloc)
pub fn hot_path(n: usize) -> Vec<u32> {
    let scratch = vec![0u8; n];
    let mut out = Vec::with_capacity(scratch.len());
    for i in 0..n {
        out.push(i as u32);
    }
    out
}
