//! Seeded truncating-cast violation (this file is in `[cast] files`).

pub fn offset(v: u64) -> usize {
    v as usize
}
