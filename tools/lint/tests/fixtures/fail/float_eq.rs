//! Seeded numeric-safety violation: exact float equality.

pub fn is_zero(x: f32) -> bool {
    x == 0.0
}
