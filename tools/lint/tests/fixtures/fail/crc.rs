//! Seeded CRC-coverage violations: an unbalanced `begin_section` and a
//! `StreamWriter` that is created but never `finish()`ed.

pub fn unbalanced(w: &mut W) {
    w.begin_section("edges");
    w.write_u64(4);
}

pub fn unfinished(path: &str) {
    let mut w = StreamWriter::create(path);
    w.begin_section("nodes");
    w.end_section();
}
