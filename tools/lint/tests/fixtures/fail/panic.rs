//! Seeded panic-surface violations: `.unwrap()`, `.expect("…")`,
//! `panic!`, and slice indexing — one of each, all in library code.

pub fn first(v: &[u32]) -> u32 {
    let head = v.get(0).unwrap();
    let tail = v[1];
    head + tail
}

pub fn must(v: Option<u32>) -> u32 {
    v.expect("fixture: must be set")
}

pub fn boom() {
    panic!("fixture");
}
