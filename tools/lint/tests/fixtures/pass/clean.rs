//! Clean fixture: the allowed / recoverable counterpart of every seeded
//! violation class. Must produce zero violations under the fixture
//! config — this is the golden "pass" half of the gate tests.

use std::sync::Mutex;

pub struct S {
    hot: Mutex<u32>,
    state: Mutex<u32>,
}

impl S {
    pub fn ordered(&self) {
        let a = self.hot.lock();
        let b = self.state.lock();
        drop((a, b));
    }
}

pub fn first(v: &[u32]) -> Option<u32> {
    v.first().copied()
}

// lint: allow(panic, "fixture: fn-scope allow with a reason covers indexing too")
pub fn head(v: &[u32]) -> u32 {
    v[0]
}

pub fn masked(x: f32) -> bool {
    // lint: allow(float-eq, "fixture: exact 0.0/1.0 mask sentinel")
    x == 0.0
}

pub fn offset(v: u64) -> Result<usize, &'static str> {
    usize::try_from(v).map_err(|_| "offset overflows usize")
}

pub fn balanced(w: &mut W) {
    w.begin_section("edges");
    w.write_u64(4);
    w.end_section();
}

// lint: deny(alloc)
pub fn fill(out: &mut [f32]) {
    for x in out.iter_mut() {
        *x = 0.5;
    }
}
