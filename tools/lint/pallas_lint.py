#!/usr/bin/env python3
"""pallas-lint: in-tree static invariant checker for the TGL rust sources.

The repo's hardest-won guarantees — zero steady-state allocation, panic-free
library paths, single-owner shard state, CRC-covered containers — were
previously enforced only by runtime tests that must *hit* the offending
path. This tool makes them structural properties of the source: it lexes
`rust/src` with its own small Rust lexer (raw strings, nested block
comments, lifetimes vs char literals, attribute spans) and walks the token
stream with a rule engine. No Rust toolchain and no third-party Python
packages are required, so the gate runs even in containers where `cargo`
is absent, in well under two seconds.

Rules (rule ids in parentheses):

  panic-surface (`panic`, `index`)
      `.unwrap()` / `.expect(` / `panic!` / `unreachable!` / `todo!` /
      `unimplemented!` (id `panic`) and slice indexing `expr[...]`
      (id `index`) in non-`#[cfg(test)]` library code.
  deny-alloc regions (`alloc`)
      Allocating constructs (`Vec::new`, `vec![`, `to_vec`, `collect`,
      `format!`, `Box::new`, `String::…`, `to_string`, `to_owned`,
      `Arc::new`, …) inside functions annotated `// lint: deny(alloc)`.
  concurrency hygiene (`spawn`, `lock`)
      `thread::spawn` outside the files named in `[spawn] allow_files`;
      `.lock()` receivers must appear in the `[locks]` rank table and,
      within one function, must be acquired in non-decreasing rank order.
  numeric safety (`float-eq`, `cast`)
      `==` / `!=` with a float operand; truncating `as` casts to the
      `[cast] targets` types inside the `[cast] files` list.
  binfmt CRC coverage (`crc`)
      In the `[crc] files` list, every `begin_section` must be balanced by
      an `end_section` in the same function, and a function creating a
      `StreamWriter` must also `finish()` it (or hand it off explicitly).

Allowlist grammar (in-source, reasons mandatory):

  // lint: allow(<rule>, "<reason>")      trailing → that line only;
                                          standalone → next line, or the
                                          whole next item when that item
                                          is a fn/mod/impl
  // lint: allow-file(<rule>, "<reason>") whole file
  // lint: deny(alloc)                    next fn is a deny-alloc region

`allow(panic, …)` also covers `index` violations (they are one rule
class); `allow(index, …)` covers only indexing. An allow with a missing
or empty reason is itself a violation; an allow that matches nothing is
reported as a warning so stale entries get pruned.

Exit codes: 0 clean, 1 violations, 2 usage/config errors.
"""

import os
import re
import sys
import bisect

# --------------------------------------------------------------- tokens

WS = "ws"
COMMENT = "comment"
IDENT = "ident"
LIFETIME = "lifetime"
CHAR = "char"
STR = "str"
NUM = "num"
FLOAT = "float"  # numeric literal that is a float (`.`/exponent/f32/f64)
PUNCT = "punct"

# Longest-match first.
_PUNCTS = [
    "<<=", ">>=", "...", "..=",
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "<<", ">>",
    "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "..",
    "+", "-", "*", "/", "%", "^", "!", "&", "|", "=", ">", "<", "@", "_",
    ".", ",", ";", ":", "#", "$", "?", "(", ")", "[", "]", "{", "}",
]

_IDENT_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
_IDENT_CONT = _IDENT_START | set("0123456789")
_HEX = set("0123456789abcdefABCDEF_")


class Tok:
    __slots__ = ("kind", "text", "line", "col")

    def __init__(self, kind, text, line, col):
        self.kind = kind
        self.text = text
        self.line = line
        self.col = col

    def __repr__(self):
        return f"Tok({self.kind}, {self.text!r}, {self.line}:{self.col})"


class LexError(Exception):
    pass


# One alternation drives the scanner; the rare constructs that a regex
# cannot express (nested block comments) fall out to a manual scan. Order
# matters: raw strings before idents (`r"…"`), chars before lifetimes
# (`'a'` vs `'a`), multi-char puncts before their prefixes.
_MASTER = re.compile(
    r"""
      (?P<ws>\s+)
    | (?P<lcom>//[^\n]*)
    | (?P<bcom>/\*)
    | (?P<raw>b?r(?P<hashes>\#*)"(?s:.*?)"(?P=hashes))
    | (?P<str>b?"(?:\\[\s\S]|[^"\\])*")
    | (?P<char>b?'(?:\\(?:u\{[^}']*\}|[^u])|[^'\\])')
    | (?P<life>'[A-Za-z_][A-Za-z0-9_]*)
    | (?P<num>
          0[xob][0-9a-fA-F_]*[A-Za-z0-9_]*
        | [0-9][0-9_]*
          (?: \.[0-9][0-9_]* | \.(?![.A-Za-z_]) )?
          (?: [eE][+-]?[0-9][0-9_]* )?
          [A-Za-z0-9_]*
      )
    | (?P<id>(?:r\#)?[A-Za-z_][A-Za-z0-9_]*)
    | (?P<punct><<=|>>=|\.\.\.|\.\.=|::|->|=>|==|!=|<=|>=|&&|\|\||<<|>>
        |\+=|-=|\*=|/=|%=|\^=|&=|\|=|\.\.
        |[-+*/%^!&|=><@_.,;:\#$?()\[\]{}])
    """,
    re.VERBOSE,
)

_FLOAT_TAIL = re.compile(r"[eE][+-]?[0-9]")


def _num_is_float(text):
    if text.startswith(("0x", "0o", "0b")):
        return False
    if text.endswith(("f32", "f64")):
        return True
    if "." in text:
        return True
    return bool(_FLOAT_TAIL.search(text))


def lex(src, path="<str>"):
    """Tokenize Rust source. Whitespace is dropped; comments are kept
    (the allowlist directives live in them)."""
    toks = []
    append = toks.append
    # newline offsets for O(log n) line/col lookup
    nl = [m.start() for m in re.finditer("\n", src)]

    def linecol(off):
        li = bisect.bisect_right(nl, off - 1)
        start = nl[li - 1] + 1 if li else 0
        return li + 1, off - start + 1

    i, n = 0, len(src)
    while i < n:
        m = _MASTER.match(src, i)
        if m is None:
            line, col = linecol(i)
            raise LexError(f"{path}:{line}:{col}: unexpected byte {src[i]!r}")
        kind = m.lastgroup
        end = m.end()
        if kind == "hashes":  # inner group of raw; lastgroup picks innermost
            kind = "raw"
        if kind == "ws":
            i = end
            continue
        if kind == "bcom":
            # nested block comment: manual scan
            depth, j = 1, i + 2
            while j < n and depth:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            if depth:
                line, _ = linecol(i)
                raise LexError(f"{path}:{line}: unterminated block comment")
            end = j
            text = src[i:end]
            line, col = linecol(i)
            append(Tok(COMMENT, text, line, col))
            i = end
            continue
        text = m.group(0)
        line, col = linecol(i)
        if kind == "lcom":
            append(Tok(COMMENT, text, line, col))
        elif kind == "raw" or kind == "str":
            append(Tok(STR, text, line, col))
        elif kind == "char":
            append(Tok(CHAR, text, line, col))
        elif kind == "life":
            append(Tok(LIFETIME, text, line, col))
        elif kind == "num":
            append(Tok(FLOAT if _num_is_float(text) else NUM, text, line, col))
        elif kind == "id":
            append(Tok(IDENT, text, line, col))
        else:
            append(Tok(PUNCT, text, line, col))
        i = end
    return toks


# ------------------------------------------------------------ structure

_QUALS = {"pub", "const", "unsafe", "async", "extern", "crate", "in", "super", "self", "default"}
_ITEM_KW = {"fn", "mod", "impl", "struct", "enum", "trait", "union"}

# Reserved words that may legitimately precede `[` without being an
# indexed value: `&mut [u8]` types, `for x in [..]`, `return [..]`,
# `match x { .. => [..] }`, and friends.
_RUST_KW = _ITEM_KW | {
    "mut", "ref", "move", "dyn", "in", "as", "let", "const", "static",
    "pub", "use", "where", "if", "else", "match", "while", "loop", "for",
    "return", "break", "continue", "unsafe", "async", "await", "box",
    "crate", "super", "self", "Self", "type", "extern", "yield",
}


class FnSpan:
    __slots__ = ("name", "kw_idx", "start_line", "body_start", "body_end", "end_line", "deny_alloc")

    def __init__(self, name, kw_idx, start_line, body_start, body_end, end_line):
        self.name = name
        self.kw_idx = kw_idx
        self.start_line = start_line
        self.body_start = body_start  # token index of `{`, or None
        self.body_end = body_end      # token index of matching `}`, or None
        self.end_line = end_line
        self.deny_alloc = False


class FileModel:
    """Lexed file plus the derived structure every rule consumes."""

    def __init__(self, path, rel, src):
        self.path = path
        self.rel = rel
        self.toks = lex(src, path)
        # significant tokens (no comments) for pattern matching
        self.sig = [t for t in self.toks if t.kind != COMMENT]
        self.attr_spans = []   # (sig_start, sig_end_exclusive, is_test)
        self.fn_spans = []     # FnSpan, in source order (may nest)
        self.test_lines = []   # merged sorted [start_line, end_line] pairs
        self.directives = []   # Directive
        self._scan_structure()
        self._scan_directives()

    # -- structure ---------------------------------------------------

    def _match_close(self, idx, open_t, close_t):
        """Index of the token closing the group opened at sig[idx]."""
        depth = 0
        sig = self.sig
        for j in range(idx, len(sig)):
            t = sig[j]
            if t.kind == PUNCT:
                if t.text == open_t:
                    depth += 1
                elif t.text == close_t:
                    depth -= 1
                    if depth == 0:
                        return j
        return len(sig) - 1

    def _scan_structure(self):
        sig = self.sig
        i = 0
        n = len(sig)
        test_spans = []
        pending_test_attr = False
        attr_set = set()
        while i < n:
            t = sig[i]
            # attributes: #[...] / #![...]
            if t.kind == PUNCT and t.text == "#":
                j = i + 1
                inner = j < n and sig[j].kind == PUNCT and sig[j].text == "!"
                if inner:
                    j += 1
                if j < n and sig[j].kind == PUNCT and sig[j].text == "[":
                    close = self._match_close(j, "[", "]")
                    is_test = any(
                        sig[k].kind == IDENT and sig[k].text == "test"
                        for k in range(j, close + 1)
                    )
                    self.attr_spans.append((i, close + 1, is_test))
                    attr_set.update(range(i, close + 1))
                    if is_test and not inner:
                        pending_test_attr = True
                    i = close + 1
                    continue
            if t.kind == IDENT and t.text == "fn" and i + 1 < n and sig[i + 1].kind == IDENT:
                name = sig[i + 1].text
                # find body start: first `{` at paren depth 0, or `;`
                depth = 0
                body_start = body_end = None
                j = i + 2
                while j < n:
                    tt = sig[j]
                    if tt.kind == PUNCT:
                        if tt.text == "(":
                            depth += 1
                        elif tt.text == ")":
                            depth -= 1
                        elif tt.text == ";" and depth == 0:
                            break
                        elif tt.text == "{" and depth == 0:
                            body_start = j
                            body_end = self._match_close(j, "{", "}")
                            break
                    j += 1
                end_line = sig[body_end].line if body_end is not None else sig[i].line
                span = FnSpan(name, i, sig[i].line, body_start, body_end, end_line)
                self.fn_spans.append(span)
                if pending_test_attr:
                    test_spans.append((sig[i].line, end_line))
                pending_test_attr = False
                i += 2
                continue
            if t.kind == IDENT and t.text == "mod" and i + 1 < n and sig[i + 1].kind == IDENT:
                # find `{` or `;`
                j = i + 2
                if j < n and sig[j].kind == PUNCT and sig[j].text == "{":
                    close = self._match_close(j, "{", "}")
                    if pending_test_attr:
                        test_spans.append((sig[i].line, sig[close].line))
                    pending_test_attr = False
                    i += 2  # descend into the mod (items inside still scanned)
                    continue
                pending_test_attr = False
                i += 1
                continue
            if t.kind == IDENT and t.text in _ITEM_KW:
                pending_test_attr = False
            i += 1
        # merge test spans into a sorted flat list for bisect lookups
        test_spans.sort()
        merged = []
        for s, e in test_spans:
            if merged and s <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], e))
            else:
                merged.append((s, e))
        self.test_lines = merged
        self._attr_tok = attr_set
        self._fn_starts = [f.start_line for f in self.fn_spans]

    def in_test(self, line):
        i = bisect.bisect_right([s for s, _ in self.test_lines], line) - 1
        return i >= 0 and self.test_lines[i][0] <= line <= self.test_lines[i][1]

    def in_attr(self, sig_idx):
        return sig_idx in self._attr_tok

    def enclosing_fn(self, line):
        """Innermost fn whose span covers `line` (None at module level)."""
        best = None
        i = bisect.bisect_right(self._fn_starts, line) - 1
        # walk back: nested fns are rare, spans are ordered by start
        while i >= 0:
            f = self.fn_spans[i]
            if f.start_line <= line <= f.end_line:
                best = f
                break
            i -= 1
        return best

    # -- directives --------------------------------------------------

    _DIRECTIVE_RE = re.compile(
        r"//[/!]?\s*lint:\s*(allow-file|allow|deny)\(\s*([\w-]+)"
        r'(?:\s*,\s*"([^"]*)")?\s*\)'
    )

    def _scan_directives(self):
        # map: line -> first significant token index on that line
        line_first_sig = {}
        for idx, t in enumerate(self.sig):
            line_first_sig.setdefault(t.line, idx)
        for ci, tok in enumerate(self.toks):
            if tok.kind != COMMENT:
                continue
            m = self._DIRECTIVE_RE.search(tok.text)
            if not m:
                if "lint:" in tok.text:
                    self.directives.append(
                        Directive("malformed", None, None, tok.line, None, self.rel)
                    )
                continue
            kind, rule, reason = m.group(1), m.group(2), m.group(3)
            # trailing if a significant token starts on the same line
            # before the comment column
            first = line_first_sig.get(tok.line)
            trailing = first is not None and self.sig[first].col < tok.col
            if self.in_test(tok.line):
                continue  # test regions are not linted; skip their allows
            if kind == "allow-file":
                self.directives.append(
                    Directive("allow", rule, reason, tok.line, ("file",), self.rel)
                )
                continue
            if trailing:
                scope = ("line", tok.line)
                target_fn = None
                for f in self.fn_spans:
                    if f.start_line == tok.line:
                        target_fn = f
                        break
            else:
                # standalone: bind to the next item (fn span) or next line
                nxt = None
                for idx, t in enumerate(self.sig):
                    if t.line > tok.line:
                        nxt = (idx, t)
                        break
                target_fn = None
                if nxt is not None:
                    # skip attribute tokens between directive and item
                    idx = nxt[0]
                    while idx < len(self.sig) and self.in_attr(idx):
                        idx += 1
                    if idx < len(self.sig):
                        probe = idx
                        # skip qualifiers: pub (crate) const unsafe async…
                        while probe < len(self.sig) and (
                            (self.sig[probe].kind == IDENT and self.sig[probe].text in _QUALS)
                            or (self.sig[probe].kind == PUNCT and self.sig[probe].text in "()")
                        ):
                            probe += 1
                        if (
                            probe < len(self.sig)
                            and self.sig[probe].kind == IDENT
                            and self.sig[probe].text == "fn"
                        ):
                            for f in self.fn_spans:
                                if f.kw_idx >= probe:
                                    target_fn = f
                                    break
                if target_fn is not None:
                    scope = ("span", target_fn.start_line, target_fn.end_line)
                elif nxt is not None:
                    scope = ("line", nxt[1].line)
                else:
                    scope = ("line", tok.line + 1)
            if kind == "deny":
                if rule != "alloc" or target_fn is None:
                    self.directives.append(
                        Directive("malformed", rule, reason, tok.line, None, self.rel)
                    )
                else:
                    target_fn.deny_alloc = True
                continue
            self.directives.append(
                Directive("allow", rule, reason, tok.line, scope, self.rel)
            )


class Directive:
    __slots__ = ("kind", "rule", "reason", "line", "scope", "rel", "used")

    def __init__(self, kind, rule, reason, line, scope, rel):
        self.kind = kind
        self.rule = rule
        self.reason = reason
        self.line = line
        self.scope = scope
        self.rel = rel
        self.used = False

    def covers(self, rule, line):
        if self.kind != "allow":
            return False
        # `panic` is the rule-class name: it also covers `index`.
        if self.rule != rule and not (self.rule == "panic" and rule == "index"):
            return False
        if self.scope[0] == "file":
            return True
        if self.scope[0] == "line":
            return line == self.scope[1]
        return self.scope[1] <= line <= self.scope[2]


# --------------------------------------------------------------- config

RULE_IDS = {"panic", "index", "alloc", "spawn", "lock", "float-eq", "cast", "crc"}

DEFAULT_CONFIG = {
    "root": "rust/src",
    "spawn_allow": ["util/pool.rs"],
    "locks": {},           # receiver ident -> (rank, label)
    "cast_files": [],
    "cast_targets": ["usize", "u32", "u16", "u8"],
    "crc_files": [],
}


class ConfigError(Exception):
    pass


def parse_config(path):
    cfg = {
        "root": DEFAULT_CONFIG["root"],
        "spawn_allow": list(DEFAULT_CONFIG["spawn_allow"]),
        "locks": {},
        "cast_files": [],
        "cast_targets": list(DEFAULT_CONFIG["cast_targets"]),
        "crc_files": [],
    }
    section = None
    with open(path, encoding="utf-8") as f:
        for ln, raw in enumerate(f, 1):
            body = raw.split("#", 1)[0].strip()
            if not body:
                continue
            if body.startswith("[") and body.endswith("]"):
                section = body[1:-1].strip()
                if section not in ("paths", "spawn", "locks", "cast", "crc"):
                    raise ConfigError(f"{path}:{ln}: unknown section [{section}]")
                continue
            if "=" not in body:
                raise ConfigError(f"{path}:{ln}: expected key = value")
            key, val = (s.strip() for s in body.split("=", 1))
            if section == "paths" and key == "root":
                cfg["root"] = val
            elif section == "spawn" and key == "allow_files":
                cfg["spawn_allow"] = [v.strip() for v in val.split(",") if v.strip()]
            elif section == "locks":
                # key = <rank> <label…>
                parts = val.split(None, 1)
                try:
                    rank = int(parts[0])
                except (ValueError, IndexError):
                    raise ConfigError(f"{path}:{ln}: lock `{key}` needs an integer rank")
                label = parts[1] if len(parts) > 1 else key
                cfg["locks"][key] = (rank, label)
            elif section == "cast" and key == "files":
                cfg["cast_files"] = [v.strip() for v in val.split(",") if v.strip()]
            elif section == "cast" and key == "targets":
                cfg["cast_targets"] = [v.strip() for v in val.split(",") if v.strip()]
            elif section == "crc" and key == "files":
                cfg["crc_files"] = [v.strip() for v in val.split(",") if v.strip()]
            else:
                raise ConfigError(f"{path}:{ln}: unknown key `{key}` in [{section}]")
    return cfg


def _file_matches(rel, patterns):
    rel = rel.replace(os.sep, "/")
    return any(rel == p or rel.endswith("/" + p) for p in patterns)


# ---------------------------------------------------------------- rules

class Violation:
    __slots__ = ("rule", "rel", "line", "col", "msg", "span")

    def __init__(self, rule, rel, line, col, msg, span=""):
        self.rule = rule
        self.rel = rel
        self.line = line
        self.col = col
        self.msg = msg
        self.span = span

    def render(self):
        where = f"{self.rel}:{self.line}:{self.col}"
        tail = f"  [{self.span}]" if self.span else ""
        return f"{where}: {self.rule}: {self.msg}{tail}"


_PANIC_MACROS = {"panic", "unreachable", "todo", "unimplemented"}
_PANIC_METHODS = {"unwrap", "expect"}
_ALLOC_MACROS = {"vec", "format"}
_ALLOC_METHODS = {"to_vec", "to_string", "to_owned", "collect"}
_ALLOC_PATHS = {
    ("Vec", "new"), ("Vec", "with_capacity"), ("Vec", "from"),
    ("VecDeque", "new"), ("VecDeque", "with_capacity"),
    ("String", "new"), ("String", "with_capacity"), ("String", "from"),
    ("Box", "new"), ("Arc", "new"), ("Rc", "new"),
    ("BTreeMap", "new"), ("HashMap", "new"), ("HashSet", "new"), ("BTreeSet", "new"),
}
_FLOAT_CONSTS = {"NEG_INFINITY", "INFINITY", "NAN", "EPSILON"}
_OPERAND_STOP = {",", ";", "{", "}", "&&", "||", "=>", "return"}


def _prev_sig(sig, i):
    return sig[i - 1] if i > 0 else None


def _skip_group_back(sig, i, close_t, open_t):
    """Given sig[i] is a closing bracket, return index before its opener."""
    depth = 0
    while i >= 0:
        t = sig[i]
        if t.kind == PUNCT:
            if t.text == close_t:
                depth += 1
            elif t.text == open_t:
                depth -= 1
                if depth == 0:
                    return i - 1
        i -= 1
    return -1


def check_file(fm, cfg, violations):
    sig = fm.sig
    n = len(sig)
    rel = fm.rel

    cast_file = _file_matches(rel, cfg["cast_files"])
    crc_file = _file_matches(rel, cfg["crc_files"])
    spawn_ok = _file_matches(rel, cfg["spawn_allow"])
    lock_seq = {}  # fn id -> (max_rank, name, line)

    for i, t in enumerate(sig):
        if fm.in_test(t.line):
            continue

        # ---- panic surface: .unwrap() / .expect( and panic-family macros
        if t.kind == IDENT and t.text in _PANIC_METHODS:
            p = _prev_sig(sig, i)
            nx = sig[i + 1] if i + 1 < n else None
            if (
                p is not None and p.kind == PUNCT and p.text == "."
                and nx is not None and nx.kind == PUNCT and nx.text == "("
            ):
                # `.expect(` is only Option/Result::expect when its argument
                # is a message string; parser-style `self.expect(b'{')`
                # methods take token arguments and are not panic sites.
                arg = sig[i + 2] if i + 2 < n else None
                if t.text == "expect" and not (
                    arg is not None and arg.kind in (STR, "raw")
                ):
                    pass
                else:
                    violations.append(Violation(
                        "panic", rel, t.line, t.col,
                        f"`.{t.text}()` in library path (recoverable error or allowlist)",
                        f".{t.text}(",
                    ))
        if t.kind == IDENT and t.text in _PANIC_MACROS:
            nx = sig[i + 1] if i + 1 < n else None
            if nx is not None and nx.kind == PUNCT and nx.text == "!":
                violations.append(Violation(
                    "panic", rel, t.line, t.col,
                    f"`{t.text}!` in library path",
                    f"{t.text}!",
                ))

        # ---- panic surface: slice indexing
        if t.kind == PUNCT and t.text == "[" and not fm.in_attr(i):
            p = _prev_sig(sig, i)
            if p is not None and (
                (p.kind == IDENT and p.text not in _RUST_KW)
                or (p.kind == PUNCT and p.text in (")", "]"))
            ):
                # `name![` macros are excluded by the `!` between; attrs by `#`
                violations.append(Violation(
                    "index", rel, t.line, t.col,
                    "slice indexing in library path (can panic; prefer get/"
                    "iterators or allowlist with the bounds argument)",
                    (p.text if p.kind == IDENT else "…") + "[",
                ))

        # ---- spawn
        if (
            t.kind == IDENT and t.text == "thread"
            and i + 2 < n
            and sig[i + 1].kind == PUNCT and sig[i + 1].text == "::"
            and sig[i + 2].kind == IDENT and sig[i + 2].text == "spawn"
            and not spawn_ok
        ):
            violations.append(Violation(
                "spawn", rel, t.line, t.col,
                "thread::spawn outside the worker-pool module "
                "(route parallelism through util/pool.rs)",
                "thread::spawn",
            ))

        # ---- locks
        if (
            t.kind == IDENT and t.text == "lock"
            and i + 1 < n and sig[i + 1].kind == PUNCT and sig[i + 1].text == "("
        ):
            p = _prev_sig(sig, i)
            if p is not None and p.kind == PUNCT and p.text == ".":
                # receiver: walk back over one balanced group if needed
                j = i - 2
                if j >= 0 and sig[j].kind == PUNCT and sig[j].text in ("]", ")"):
                    j = _skip_group_back(sig, j, sig[j].text, "[" if sig[j].text == "]" else "(")
                name = sig[j].text if j >= 0 and sig[j].kind == IDENT else "?"
                entry = cfg["locks"].get(name)
                if entry is None:
                    violations.append(Violation(
                        "lock", rel, t.line, t.col,
                        f"mutex receiver `{name}` is not in the declared "
                        "lock-order table ([locks] in lint.conf)",
                        f"{name}.lock()",
                    ))
                else:
                    rank, label = entry
                    f = fm.enclosing_fn(t.line)
                    key = id(f) if f is not None else 0
                    prior = lock_seq.get(key)
                    if prior is not None and rank < prior[0]:
                        violations.append(Violation(
                            "lock", rel, t.line, t.col,
                            f"lock-order violation: `{name}` (rank {rank}, "
                            f"{label}) acquired after `{prior[1]}` (rank "
                            f"{prior[0]}) in fn {f.name if f else '<module>'}",
                            f"{name}.lock()",
                        ))
                    if prior is None or rank > prior[0]:
                        lock_seq[key] = (rank, name, t.line)

        # ---- float comparisons
        if t.kind == PUNCT and t.text in ("==", "!="):
            if _operand_is_float(sig, i - 1, -1) or _operand_is_float(sig, i + 1, +1):
                violations.append(Violation(
                    "float-eq", rel, t.line, t.col,
                    f"float `{t.text}` comparison (use total_cmp / an epsilon, "
                    "or allowlist exact-sentinel comparisons)",
                    t.text,
                ))

        # ---- casts
        if cast_file and t.kind == IDENT and t.text == "as" and i + 1 < n:
            nx = sig[i + 1]
            if nx.kind == IDENT and nx.text in cfg["cast_targets"]:
                # skip `use … as name;` renames
                p = _prev_sig(sig, i)
                if not (p is not None and p.kind == PUNCT and p.text == "::"):
                    violations.append(Violation(
                        "cast", rel, t.line, t.col,
                        f"truncating `as {nx.text}` cast in an offset path "
                        "(use binfmt::usize_from / try_into with a named error)",
                        f"as {nx.text}",
                    ))

    # ---- CRC pairing: per-fn begin/end balance + create/finish
    if crc_file:
        for f in fm.fn_spans:
            if f.body_start is None or fm.in_test(f.start_line):
                continue
            begins = ends = creates = finishes = 0
            for j in range(f.body_start, (f.body_end or f.body_start) + 1):
                t = sig[j]
                inner = fm.enclosing_fn(t.line)
                if inner is not f:
                    continue
                if t.kind == IDENT and j > 0 and sig[j - 1].kind == PUNCT and sig[j - 1].text == ".":
                    if t.text == "begin_section":
                        begins += 1
                    elif t.text == "end_section":
                        ends += 1
                    elif t.text == "finish":
                        finishes += 1
                if (
                    t.kind == IDENT and t.text == "StreamWriter"
                    and j + 2 < n
                    and sig[j + 1].kind == PUNCT and sig[j + 1].text == "::"
                    and sig[j + 2].kind == IDENT and sig[j + 2].text == "create"
                ):
                    creates += 1
            if begins != ends:
                violations.append(Violation(
                    "crc", rel, f.start_line, 1,
                    f"fn {f.name}: {begins} begin_section vs {ends} end_section "
                    "— every section write must be closed (and CRC'd) before "
                    "the footer",
                    f.name,
                ))
            if creates > 0 and finishes == 0:
                violations.append(Violation(
                    "crc", rel, f.start_line, 1,
                    f"fn {f.name}: StreamWriter created but never finish()ed — "
                    "the footer checksum is only written by finish()",
                    f.name,
                ))

    # ---- deny-alloc regions
    for f in fm.fn_spans:
        if not f.deny_alloc or f.body_start is None:
            continue
        for j in range(f.body_start, (f.body_end or f.body_start) + 1):
            t = sig[j]
            if fm.in_test(t.line):
                continue
            hit = None
            if t.kind == IDENT and t.text in _ALLOC_MACROS:
                nx = sig[j + 1] if j + 1 < n else None
                if nx is not None and nx.kind == PUNCT and nx.text == "!":
                    hit = f"{t.text}!"
            elif t.kind == IDENT and t.text in _ALLOC_METHODS:
                p = _prev_sig(sig, j)
                nx = sig[j + 1] if j + 1 < n else None
                if (
                    p is not None and p.kind == PUNCT and p.text == "."
                    and nx is not None and nx.kind == PUNCT and nx.text in ("(", "::")
                ):
                    hit = f".{t.text}"
            elif t.kind == IDENT and j + 2 < n:
                nx, nx2 = sig[j + 1], sig[j + 2]
                if (
                    nx.kind == PUNCT and nx.text == "::"
                    and nx2.kind == IDENT
                    and (t.text, nx2.text) in _ALLOC_PATHS
                ):
                    hit = f"{t.text}::{nx2.text}"
            if hit:
                violations.append(Violation(
                    "alloc", rel, t.line, t.col,
                    f"allocating construct `{hit}` inside deny(alloc) fn "
                    f"{f.name} (hot path must stay zero-allocation)",
                    hit,
                ))


def _operand_is_float(sig, i, step):
    """Scan a few tokens from a comparison operator looking for a float
    literal / f32|f64 path / float const, stopping at expression edges."""
    depth = 0
    seen = 0
    while 0 <= i < len(sig) and seen < 6:
        t = sig[i]
        if t.kind == PUNCT:
            if t.text in _OPERAND_STOP:
                return False
            if t.text in ("(", "["):
                depth += step
            elif t.text in (")", "]"):
                depth -= step
            if depth < 0:
                return False
        if t.kind == FLOAT:
            return True
        if t.kind == IDENT and t.text in ("f32", "f64"):
            return True
        if t.kind == IDENT and t.text in _FLOAT_CONSTS:
            return True
        if t.kind == IDENT and t.text in ("as",):
            # `x as f32 == y` — the cast target decides
            pass
        i += step
        seen += 1
    return False


# --------------------------------------------------------------- driver

def collect_files(root):
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for name in sorted(filenames):
            if name.endswith(".rs"):
                out.append(os.path.join(dirpath, name))
    return out


def run(paths, cfg, list_allows=False, out=sys.stdout):
    violations = []
    warnings = []
    models = []
    files = []
    for p in paths:
        if os.path.isdir(p):
            files.extend(collect_files(p))
        else:
            files.append(p)
    base = os.path.commonpath([os.path.abspath(p) for p in paths]) if paths else "."
    if os.path.isfile(base):
        base = os.path.dirname(base)
    for path in files:
        rel = os.path.relpath(os.path.abspath(path), base).replace(os.sep, "/")
        try:
            with open(path, encoding="utf-8") as f:
                src = f.read()
            fm = FileModel(path, rel, src)
        except (LexError, UnicodeDecodeError) as e:
            violations.append(Violation("lex", rel, 1, 1, str(e)))
            continue
        models.append(fm)
        check_file(fm, cfg, violations)

    # apply allows; validate directives
    allows = [d for fm in models for d in fm.directives]
    for d in allows:
        if d.kind == "malformed":
            violations.append(Violation(
                "directive", d.rel, d.line, 1,
                "malformed lint directive (grammar: "
                '`// lint: allow(<rule>, "<reason>")`, '
                '`// lint: allow-file(<rule>, "<reason>")`, '
                "`// lint: deny(alloc)` before a fn)",
            ))
        elif d.rule not in RULE_IDS:
            violations.append(Violation(
                "directive", d.rel, d.line, 1,
                f"allow names unknown rule `{d.rule}` "
                f"(rules: {', '.join(sorted(RULE_IDS))})",
            ))
        elif not d.reason or not d.reason.strip():
            violations.append(Violation(
                "directive", d.rel, d.line, 1,
                f"allow({d.rule}) without a reason — every allowlist entry "
                "must explain why the site is safe",
            ))

    kept = []
    for v in violations:
        if v.rule in ("directive", "lex"):
            kept.append(v)
            continue
        suppressed = False
        for d in allows:
            if d.rel == v.rel and d.covers(v.rule, v.line):
                d.used = True
                suppressed = True
                break
        if not suppressed:
            kept.append(v)
    for d in allows:
        if d.kind == "allow" and d.rule in RULE_IDS and d.reason and not d.used:
            warnings.append(
                f"{d.rel}:{d.line}: warning: unused allow({d.rule}) — prune it"
            )

    if list_allows:
        for d in sorted(allows, key=lambda d: (d.rel, d.line)):
            if d.kind == "allow":
                scope = d.scope[0] if d.scope else "?"
                print(
                    f"{d.rel}:{d.line}: allow({d.rule}) [{scope}] — {d.reason}",
                    file=out,
                )
        return 0

    kept.sort(key=lambda v: (v.rel, v.line, v.col))
    for v in kept:
        print(v.render(), file=out)
    for w in warnings:
        print(w, file=out)
    n_allows = sum(1 for d in allows if d.kind == "allow")
    print(
        f"pallas-lint: {len(kept)} violation(s), {len(files)} file(s), "
        f"{n_allows} allowlist entr{'y' if n_allows == 1 else 'ies'}",
        file=out,
    )
    return 1 if kept else 0


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    config_path = None
    list_allows = False
    paths = []
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--config":
            i += 1
            if i >= len(argv):
                print("pallas-lint: --config needs a path", file=sys.stderr)
                return 2
            config_path = argv[i]
        elif a == "--list-allows":
            list_allows = True
        elif a in ("-h", "--help"):
            print(__doc__)
            return 0
        elif a.startswith("-"):
            print(f"pallas-lint: unknown flag {a}", file=sys.stderr)
            return 2
        else:
            paths.append(a)
        i += 1

    here = os.path.dirname(os.path.abspath(__file__))
    if config_path is None:
        config_path = os.path.join(here, "lint.conf")
    try:
        cfg = parse_config(config_path)
    except (ConfigError, OSError) as e:
        print(f"pallas-lint: config error: {e}", file=sys.stderr)
        return 2
    if not paths:
        # default root is relative to the repo (two levels above tools/lint)
        repo = os.path.dirname(os.path.dirname(here))
        paths = [os.path.join(repo, cfg["root"])]
        if not os.path.isdir(paths[0]):
            print(f"pallas-lint: source root {paths[0]} not found", file=sys.stderr)
            return 2
    return run(paths, cfg, list_allows=list_allows)


if __name__ == "__main__":
    sys.exit(main())
