"""Layer 2: the TGL model zoo in JAX (JODIE / DySAT / TGAT / TGN / APAN).

Every variant is assembled from the paper's unified component set — node
memory (Eq. 1–5), the time encoder Φ (Eq. 3), the attention aggregator
(§2.2), the memory updater UPDT (Eq. 4) — all of whose hot-spots are the
Pallas kernels in :mod:`compile.kernels`. Three step functions are lowered
per variant:

- ``train`` — memory refresh + message passing + link-prediction BCE loss
  + backprop + Adam, all in one graph (optimizer-in-graph keeps Python off
  the training path entirely).
- ``eval``  — loss/scores/embeddings + the same memory/mail updates (the
  paper keeps updating node memory during inference, §3).
- ``embed`` — embeddings for an arbitrary root batch at given timestamps
  (node-classification readout), read-only on memory.

Parameters travel as ONE flat f32 vector; :class:`ParamBuilder` records
the (name, offset, shape) layout into the manifest so the Rust coordinator
can initialize, checkpoint, and average replicas without Python.

Input-ordering contract with the Rust trainer (`Mfg::all_nodes`): node-
aligned tensors cover, in order, the B0 = 3·bs batch roots
(src | dst | neg), then for each snapshot s and hop l the flattened
sampled slots of that (s, l) block. Hop-aligned tensors (`dt_s{s}_h{l}`,
`mask_s{s}_h{l}`, `efeat_s{s}_h{l}`) follow the same (s, l) order.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import attention_op, gru_op, rnn_op, time_encode_op


# --------------------------------------------------------------------- dims


@dataclass
class Dims:
    """Static dimensions a variant is lowered with."""

    bs: int = 600          # positive edges per batch
    fanout: int = 10       # K
    hops: int = 1          # L
    snapshots: int = 1     # S
    dm: int = 100          # memory dim
    dh: int = 100          # embedding dim (== dm for memory variants)
    dv: int = 100          # node feature dim
    de: int = 100          # edge feature dim
    d_time: int = 100      # time encoding dim
    heads: int = 2
    mail_slots: int = 1
    num_classes: int = 2

    @property
    def b0(self) -> int:
        return 3 * self.bs

    @property
    def maild(self) -> int:
        return 2 * self.dm + self.de

    def hop_roots(self, l: int) -> int:
        """Roots of hop l (block row count)."""
        return self.b0 * self.fanout**l

    @property
    def n_total(self) -> int:
        """Total nodes in MFG order (roots + all sampled slots)."""
        n = self.b0
        for _ in range(self.snapshots):
            for l in range(self.hops):
                n += self.hop_roots(l) * self.fanout
        return n

    def hop_offset(self, s: int, l: int) -> int:
        """Offset of snapshot s / hop l's slots in the node axis."""
        n = self.b0
        per_snap = sum(self.hop_roots(j) * self.fanout for j in range(self.hops))
        n += s * per_snap
        for j in range(l):
            n += self.hop_roots(j) * self.fanout
        return n


# ------------------------------------------------------------ param packing


class ParamBuilder:
    """Named blocks inside one flat parameter vector."""

    def __init__(self):
        self.entries = []  # (name, offset, shape, init)
        self.size = 0

    def add(self, name, shape, init="glorot"):
        self.entries.append((name, self.size, tuple(shape), init))
        self.size += int(np.prod(shape))

    def init_flat(self, key) -> np.ndarray:
        out = np.zeros(self.size, np.float32)
        for name, off, shape, init in self.entries:
            n = int(np.prod(shape))
            key, sub = jax.random.split(key)
            if init == "glorot":
                fan_in = shape[0] if len(shape) > 1 else n
                fan_out = shape[-1]
                lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
                vals = jax.random.uniform(sub, (n,), jnp.float32, -lim, lim)
                out[off : off + n] = np.asarray(vals)
            elif init == "zeros":
                pass
            elif init == "ones":
                out[off : off + n] = 1.0
            elif init == "time":
                # TGAT's ω init: decaying frequencies over the encoding dim.
                d = shape[0]
                out[off : off + n] = (1.0 / 10.0 ** np.linspace(0, 9, d)).astype(np.float32)
            else:
                raise ValueError(init)
        return out

    def unpacker(self):
        entries = list(self.entries)

        def unpack(flat):
            return {
                name: jax.lax.dynamic_slice(flat, (off,), (int(np.prod(shape)),)).reshape(shape)
                for name, off, shape, _ in entries
            }

        return unpack

    def manifest(self):
        return [
            {"name": n, "offset": o, "shape": list(s)} for n, o, s, _ in self.entries
        ]


# ------------------------------------------------------------- model pieces


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def softplus(x):
    return jnp.logaddexp(x, 0.0)


@dataclass
class Spec:
    """What distinguishes one variant (paper Table 1)."""

    name: str
    memory: str | None      # None | 'gru' | 'rnn' | 'attn_gru'
    hops: int
    snapshots: int
    mail_slots: int = 1
    time_proj: bool = False  # JODIE's embedding projection
    recent: bool = True      # sampling strategy hint (for the Rust side)


SPECS = {
    "tgat": Spec("tgat", memory=None, hops=2, snapshots=1, recent=False),
    "tgn": Spec("tgn", memory="gru", hops=1, snapshots=1),
    "jodie": Spec("jodie", memory="rnn", hops=0, snapshots=1, time_proj=True),
    "apan": Spec("apan", memory="attn_gru", hops=0, snapshots=1, mail_slots=10),
    "dysat": Spec("dysat", memory=None, hops=2, snapshots=3, recent=False),
}


def build_params(spec: Spec, d: Dims) -> ParamBuilder:
    p = ParamBuilder()
    p.add("time_w", (d.d_time,), "time")
    p.add("time_phi", (d.d_time,), "zeros")
    p.add("feat_w", (d.dv, d.dh))
    p.add("feat_b", (d.dh,), "zeros")
    p.add("ln_in_g", (d.dh,), "ones")
    p.add("ln_in_b", (d.dh,), "zeros")
    if spec.memory in ("gru", "attn_gru"):
        xdim = d.maild + d.d_time if spec.memory == "gru" else d.dm
        p.add("upd_wi", (xdim, 3 * d.dm))
        p.add("upd_wh", (d.dm, 3 * d.dm))
        p.add("upd_bi", (3 * d.dm,), "zeros")
        p.add("upd_bh", (3 * d.dm,), "zeros")
    elif spec.memory == "rnn":
        xdim = d.maild + d.d_time
        p.add("upd_wi", (xdim, d.dm))
        p.add("upd_wh", (d.dm, d.dm))
        p.add("upd_b", (d.dm,), "zeros")
    if spec.memory == "attn_gru":  # APAN's COMB over the mailbox
        p.add("comb_wq", (d.dm + d.d_time, d.dm))
        p.add("comb_wk", (d.maild + d.d_time, d.dm))
        p.add("comb_wv", (d.maild + d.d_time, d.dm))
    for l in range(spec.hops):
        dq = d.dh + d.d_time
        dk = d.dh + d.d_time + d.de
        p.add(f"att{l}_wq", (dq, d.dh))
        p.add(f"att{l}_wk", (dk, d.dh))
        p.add(f"att{l}_wv", (dk, d.dh))
        p.add(f"att{l}_wo", (2 * d.dh, d.dh))
        p.add(f"att{l}_bo", (d.dh,), "zeros")
        p.add(f"att{l}_ln_g", (d.dh,), "ones")
        p.add(f"att{l}_ln_b", (d.dh,), "zeros")
    if spec.snapshots > 1:  # DySAT combine-RNN across snapshots
        p.add("snap_wi", (d.dh, 3 * d.dh))
        p.add("snap_wh", (d.dh, 3 * d.dh))
        p.add("snap_bi", (3 * d.dh,), "zeros")
        p.add("snap_bh", (3 * d.dh,), "zeros")
    if spec.time_proj:
        p.add("jp_w", (d.dh, d.dh))
        p.add("jp_b", (d.dh,), "zeros")
        p.add("jt_w", (d.dh,), "zeros")
    p.add("ln_out_g", (d.dh,), "ones")
    p.add("ln_out_b", (d.dh,), "zeros")
    p.add("dec_w1", (2 * d.dh, d.dh))
    p.add("dec_b1", (d.dh,), "zeros")
    p.add("dec_w2", (d.dh, 1))
    p.add("dec_b2", (1,), "zeros")
    return p


def refresh_memory(spec: Spec, d: Dims, P, mem, mail, mail_dt, mail_mask):
    """UPDT from cached mails (Eq. 4); identity where no mail is cached."""
    phi0 = time_encode_op(mail_dt[:, 0], P["time_w"], P["time_phi"])
    if spec.memory == "gru":
        x = jnp.concatenate([mail[:, 0], phi0], axis=-1)
        upd = gru_op(x, mem, P["upd_wi"], P["upd_wh"], P["upd_bi"], P["upd_bh"])
        has = mail_mask[:, 0:1]
    elif spec.memory == "rnn":
        x = jnp.concatenate([mail[:, 0], phi0], axis=-1)
        upd = rnn_op(x, mem, P["upd_wi"], P["upd_wh"], P["upd_b"])
        has = mail_mask[:, 0:1]
    elif spec.memory == "attn_gru":
        n, m, _ = mail.shape
        phi = time_encode_op(mail_dt.reshape(-1), P["time_w"], P["time_phi"]).reshape(
            n, m, d.d_time
        )
        kv = jnp.concatenate([mail, phi], axis=-1)
        q = jnp.concatenate(
            [mem, time_encode_op(jnp.zeros(n), P["time_w"], P["time_phi"])], axis=-1
        )
        ctx = attention_op(q, kv, mail_mask, P["comb_wq"], P["comb_wk"], P["comb_wv"], d.heads)
        upd = gru_op(ctx, mem, P["upd_wi"], P["upd_wh"], P["upd_bi"], P["upd_bh"])
        has = jnp.max(mail_mask, axis=1, keepdims=True)
    else:
        raise AssertionError
    return has * upd + (1.0 - has) * mem


def attention_layer(d: Dims, P, l, h_root, h_nbr, dt, mask, efeat):
    """One temporal-attention aggregation + projection + LayerNorm."""
    r, k = mask.shape
    phi = time_encode_op(dt.reshape(-1), P["time_w"], P["time_phi"]).reshape(r, k, d.d_time)
    phi_q = time_encode_op(jnp.zeros(r), P["time_w"], P["time_phi"])
    q = jnp.concatenate([h_root, phi_q], axis=-1)
    kv = jnp.concatenate([h_nbr, phi, efeat], axis=-1)
    ctx = attention_op(q, kv, mask, P[f"att{l}_wq"], P[f"att{l}_wk"], P[f"att{l}_wv"], d.heads)
    out = jnp.concatenate([ctx, h_root], axis=-1) @ P[f"att{l}_wo"] + P[f"att{l}_bo"]
    out = jax.nn.relu(out)
    return layer_norm(out, P[f"att{l}_ln_g"], P[f"att{l}_ln_b"])


def embeddings(spec: Spec, d: Dims, P, inp):
    """Dynamic node embeddings for the B0 roots; also returns the
    refreshed memory for all N nodes (to persist host-side)."""
    n = d.n_total
    if spec.memory is not None:
        mem1 = refresh_memory(
            spec, d, P, inp["mem"], inp["mail"], inp["mail_dt"], inp["mail_mask"]
        )
        h0 = mem1 + jax.nn.relu(inp["node_feat"] @ P["feat_w"] + P["feat_b"])
    else:
        mem1 = None
        h0 = jax.nn.relu(inp["node_feat"] @ P["feat_w"] + P["feat_b"])
    h0 = layer_norm(h0, P["ln_in_g"], P["ln_in_b"])
    _ = n
    b0 = d.b0
    snap_embs = []
    for s in range(d.snapshots):
        if spec.hops == 0:
            h = h0[:b0]
        elif spec.hops == 1:
            o1 = d.hop_offset(s, 0)
            l1 = d.hop_roots(0) * d.fanout
            h_nbr = h0[o1 : o1 + l1].reshape(d.b0, d.fanout, d.dh)
            h = attention_layer(
                d, P, 0, h0[:b0],
                h_nbr,
                inp[f"dt_s{s}_h0"], inp[f"mask_s{s}_h0"], inp[f"efeat_s{s}_h0"],
            )
        elif spec.hops == 2:
            o1 = d.hop_offset(s, 0)
            l1 = d.hop_roots(0) * d.fanout
            o2 = d.hop_offset(s, 1)
            l2 = d.hop_roots(1) * d.fanout
            # Inner layer: embed the hop-1 slots from their hop-2 neighbors.
            h1_roots = h0[o1 : o1 + l1]
            h2_nbr = h0[o2 : o2 + l2].reshape(l1, d.fanout, d.dh)
            h1 = attention_layer(
                d, P, 1, h1_roots, h2_nbr,
                inp[f"dt_s{s}_h1"], inp[f"mask_s{s}_h1"], inp[f"efeat_s{s}_h1"],
            )
            # Mask out padding hop-1 roots so they contribute nothing new.
            h1 = h1 * inp[f"mask_s{s}_h0"].reshape(l1, 1)
            h = attention_layer(
                d, P, 0, h0[:b0], h1.reshape(d.b0, d.fanout, d.dh),
                inp[f"dt_s{s}_h0"], inp[f"mask_s{s}_h0"], inp[f"efeat_s{s}_h0"],
            )
        else:
            raise AssertionError("hops > 2 not lowered")
        snap_embs.append(h)

    if d.snapshots > 1:
        # DySAT: GRU over snapshots, oldest -> newest.
        h = jnp.zeros_like(snap_embs[0])
        for s in reversed(range(d.snapshots)):
            h = gru_op(snap_embs[s], h, P["snap_wi"], P["snap_wh"], P["snap_bi"], P["snap_bh"])
    else:
        h = snap_embs[0]

    if spec.time_proj:
        # JODIE: embedding projection by elapsed time.
        grow = 1.0 + (inp["mem_dt"][:b0, None] * inp["dt_scale"]) * P["jt_w"][None, :]
        h = grow * (h @ P["jp_w"] + P["jp_b"])

    return layer_norm(h, P["ln_out_g"], P["ln_out_b"]), mem1


def decoder(P, h_u, h_v):
    x = jnp.concatenate([h_u, h_v], axis=-1)
    x = jax.nn.relu(x @ P["dec_w1"] + P["dec_b1"])
    return (x @ P["dec_w2"] + P["dec_b2"])[:, 0]


def link_loss(P, d: Dims, emb, edge_mask):
    pos = decoder(P, emb[: d.bs], emb[d.bs : 2 * d.bs])
    neg = decoder(P, emb[: d.bs], emb[2 * d.bs :])
    per_edge = softplus(-pos) + softplus(neg)
    denom = jnp.maximum(jnp.sum(edge_mask), 1.0)
    return jnp.sum(per_edge * edge_mask) / denom, pos, neg


def new_mails(d: Dims, mem1, batch_efeat):
    """Eq. 1–2 minus the Φ term (encoded at consume time from mail age):
    mail(u) = s_u || s_v || e_uv, mail(v) = s_v || s_u || e_uv."""
    s_u = mem1[: d.bs]
    s_v = mem1[d.bs : 2 * d.bs]
    m_src = jnp.concatenate([s_u, s_v, batch_efeat], axis=-1)
    m_dst = jnp.concatenate([s_v, s_u, batch_efeat], axis=-1)
    return jnp.concatenate([m_src, m_dst], axis=0)


# ------------------------------------------------------------ step builders


def input_specs(spec: Spec, d: Dims, kind: str):
    """(name, shape) list defining the exact function signature."""
    ins = []
    if kind == "train":
        ins += [("params", None), ("adam_m", None), ("adam_v", None),
                ("step", ()), ("lr", ())]
    else:
        ins += [("params", None)]
    ins += [("edge_mask", (d.bs,))]
    n = d.n_total
    if spec.memory is not None:
        ins += [
            ("mem", (n, d.dm)),
            ("mem_dt", (n,)),
            ("mail", (n, d.mail_slots, d.maild)),
            ("mail_dt", (n, d.mail_slots)),
            ("mail_mask", (n, d.mail_slots)),
        ]
    ins += [("node_feat", (n, d.dv))]
    if spec.memory is not None:
        ins += [("batch_efeat", (d.bs, d.de))]
    for s in range(d.snapshots):
        for l in range(spec.hops):
            r = d.b0 * d.fanout**l
            ins += [
                (f"dt_s{s}_h{l}", (r, d.fanout)),
                (f"mask_s{s}_h{l}", (r, d.fanout)),
                (f"efeat_s{s}_h{l}", (r, d.fanout, d.de)),
            ]
    if spec.time_proj:
        ins += [("dt_scale", ())]
    return ins


def make_steps(spec: Spec, d: Dims, pb: ParamBuilder):
    """Build the train / eval / embed python callables + their specs."""
    unpack = pb.unpacker()

    def forward(flat_params, inp):
        P = unpack(flat_params)
        emb, mem1 = embeddings(spec, d, P, inp)
        loss, pos, neg = link_loss(P, d, emb, inp["edge_mask"])
        outs = {"emb": emb, "pos_score": pos, "neg_score": neg}
        if spec.memory is not None:
            outs["new_mem"] = mem1
            outs["new_mail"] = new_mails(d, mem1, inp["batch_efeat"])
        return loss, outs

    train_ins = input_specs(spec, d, "train")
    eval_ins = input_specs(spec, d, "eval")

    def train_step(*args):
        names = [n for n, _ in train_ins]
        a = dict(zip(names, args))
        inp = {k: v for k, v in a.items() if k not in ("params", "adam_m", "adam_v", "step", "lr")}

        def loss_fn(flat):
            loss, outs = forward(flat, inp)
            return loss, outs

        (loss, outs), g = jax.value_and_grad(loss_fn, has_aux=True)(a["params"])
        # Adam (in-graph).
        b1, b2, eps = 0.9, 0.999, 1e-8
        t = a["step"] + 1.0
        m = b1 * a["adam_m"] + (1 - b1) * g
        v = b2 * a["adam_v"] + (1 - b2) * g * g
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        new_params = a["params"] - a["lr"] * mhat / (jnp.sqrt(vhat) + eps)
        res = {
            "loss": loss,
            "new_params": new_params,
            "new_adam_m": m,
            "new_adam_v": v,
        }
        if spec.memory is not None:
            res["new_mem"] = outs["new_mem"]
            res["new_mail"] = outs["new_mail"]
        return res

    def eval_step(*args):
        names = [n for n, _ in eval_ins]
        a = dict(zip(names, args))
        inp = {k: v for k, v in a.items() if k != "params"}
        loss, outs = forward(a["params"], inp)
        res = {
            "loss": loss,
            "pos_score": outs["pos_score"],
            "neg_score": outs["neg_score"],
            "emb": outs["emb"],
        }
        if spec.memory is not None:
            res["new_mem"] = outs["new_mem"]
            res["new_mail"] = outs["new_mail"]
        return res

    return train_step, train_ins, eval_step, eval_ins


# ---------------------------------------------------------------- clf head


def clf_param_builder(d: Dims) -> ParamBuilder:
    p = ParamBuilder()
    p.add("c_w1", (d.dh, d.dh))
    p.add("c_b1", (d.dh,), "zeros")
    p.add("c_w2", (d.dh, d.num_classes))
    p.add("c_b2", (d.num_classes,), "zeros")
    return p


def make_clf_step(d: Dims, pb: ParamBuilder):
    unpack = pb.unpacker()

    def logits_of(flat, emb):
        P = unpack(flat)
        h = jax.nn.relu(emb @ P["c_w1"] + P["c_b1"])
        return h @ P["c_w2"] + P["c_b2"]

    def clf_step(params, adam_m, adam_v, step, lr, emb, labels, mask):
        def loss_fn(flat):
            lg = logits_of(flat, emb)
            logp = jax.nn.log_softmax(lg, axis=-1)
            nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
            denom = jnp.maximum(jnp.sum(mask), 1.0)
            return jnp.sum(nll * mask) / denom, lg

        (loss, lg), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        b1, b2, eps = 0.9, 0.999, 1e-8
        t = step + 1.0
        m = b1 * adam_m + (1 - b1) * g
        v = b2 * adam_v + (1 - b2) * g * g
        new_params = params - lr * (m / (1 - b1**t)) / (jnp.sqrt(v / (1 - b2**t)) + eps)
        return {
            "loss": loss,
            "logits": lg,
            "new_params": new_params,
            "new_adam_m": m,
            "new_adam_v": v,
        }

    clf_ins = [
        ("params", (pb.size,)),
        ("adam_m", (pb.size,)),
        ("adam_v", (pb.size,)),
        ("step", ()),
        ("lr", ()),
        ("emb", (d.bs, d.dh)),
        ("labels", (d.bs,)),
        ("mask", (d.bs,)),
    ]
    return clf_step, clf_ins


# Registered by aot.py (smoke lives there); populated from configs.
VARIANT_BUILDERS: dict = {}
