"""AOT compiler: lower the TGL model zoo to HLO-text artifacts.

This is the only entry point of the Python layer and it runs exactly once,
at build time (``make artifacts``). For every model config in ``configs/``
it lowers the ``train`` / ``eval`` / ``clf`` step functions defined in
``model.py`` and writes:

- ``artifacts/<variant>_<step>.hlo.txt``  — HLO text (NOT a serialized
  ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit instruction ids
  which xla_extension 0.5.1 rejects; the text parser reassigns ids and
  round-trips cleanly — see /opt/xla-example/README.md),
- ``artifacts/<variant>_params.bin`` / ``_clf_params.bin`` — initial flat
  parameter vectors (little-endian f32),
- ``artifacts/manifest.json``             — the I/O contract the Rust
  coordinator marshals against (input order, shapes, dtypes, parameter
  layout, static dims).

Usage: ``python -m compile.aot --out ../artifacts [--variants tgn,tgat_tiny]``
"""

import argparse
import glob
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import yaml
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(x) -> dict:
    dtype = {"float32": "f32", "int32": "i32"}[str(x.dtype)]
    return {"shape": list(x.shape), "dtype": dtype}


def lower_step(fn, example_args, arg_names):
    """Lower ``fn`` at the example args; returns (hlo_text, manifest_step)."""
    # keep_unused: the manifest promises EVERY declared input is a real
    # executable parameter (some variants ignore e.g. mem_dt; jit would
    # silently drop them and desync the Rust marshalling).
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    out_shapes = jax.eval_shape(fn, *example_args)
    inputs = [dict(name=n, **spec_of(a)) for n, a in zip(arg_names, example_args)]
    # jax flattens dict outputs in sorted-key order; the manifest must list
    # outputs in that same order for the Rust side to unpack correctly.
    outputs = [dict(name=n, **spec_of(a)) for n, a in sorted(out_shapes.items())]
    return text, {"inputs": inputs, "outputs": outputs}


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def smoke_variant() -> dict:
    """Trivial variant proving the three-layer pipeline composes."""
    from jax.experimental import pallas as pl

    def kernel(w_ref, x_ref, o_ref):
        o_ref[...] = w_ref[...] @ x_ref[...] + 2.0

    def apply(w, x):
        y = pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((2, 2), jnp.float32),
            interpret=True,
        )(w, x)
        return {"y": y}

    text, step = lower_step(apply, (f32((2, 2)), f32((2, 2))), ["w", "x"])
    return {
        "model": "smoke",
        "dims": {"n": 2},
        "param_count": 0,
        "clf_param_count": 0,
        "params": [],
        "steps": {"apply": {"hlo": "smoke_apply.hlo.txt", **step}},
        "_hlo_texts": {"smoke_apply.hlo.txt": text},
        "_init": {},
    }


def build_variant(name: str, cfg: dict) -> dict:
    """Lower one configured variant (train + eval + clf)."""
    from compile import model as M

    base = M.SPECS[cfg["model"]]
    dc = cfg.get("dims", {})
    d = M.Dims(
        bs=int(dc.get("bs", 600)),
        fanout=int(dc.get("fanout", 10)),
        hops=base.hops,
        snapshots=int(dc.get("snapshots", base.snapshots)),
        dm=int(dc.get("dm", 100)),
        dh=int(dc.get("dh", 100)),
        dv=int(dc.get("dv", 100)),
        de=int(dc.get("de", 100)),
        d_time=int(dc.get("d_time", 100)),
        heads=int(dc.get("heads", 2)),
        mail_slots=int(dc.get("mail_slots", base.mail_slots)),
        num_classes=int(dc.get("num_classes", 2)),
    )
    spec = M.Spec(
        name=name,
        memory=base.memory,
        hops=base.hops,
        snapshots=d.snapshots,
        mail_slots=d.mail_slots,
        time_proj=base.time_proj,
        recent=base.recent,
    )
    pb = M.build_params(spec, d)
    train_step, train_ins, eval_step, eval_ins = M.make_steps(spec, d, pb)

    def example(ins):
        out = []
        for n, shape in ins:
            if n in ("params", "adam_m", "adam_v"):
                out.append(f32((pb.size,)))
            else:
                out.append(f32(shape))
        return tuple(out)

    texts, steps = {}, {}
    t_text, t_step = lower_step(train_step, example(train_ins), [n for n, _ in train_ins])
    texts[f"{name}_train.hlo.txt"] = t_text
    steps["train"] = {"hlo": f"{name}_train.hlo.txt", **t_step}
    e_text, e_step = lower_step(eval_step, example(eval_ins), [n for n, _ in eval_ins])
    texts[f"{name}_eval.hlo.txt"] = e_text
    steps["eval"] = {"hlo": f"{name}_eval.hlo.txt", **e_step}

    cpb = M.clf_param_builder(d)
    clf_step, clf_ins = M.make_clf_step(d, cpb)
    clf_example = []
    for n, shape in clf_ins:
        if n == "labels":
            clf_example.append(jax.ShapeDtypeStruct(shape, jnp.int32))
        else:
            clf_example.append(f32(shape))
    c_text, c_step = lower_step(clf_step, tuple(clf_example), [n for n, _ in clf_ins])
    texts[f"{name}_clf.hlo.txt"] = c_text
    steps["clf"] = {"hlo": f"{name}_clf.hlo.txt", **c_step}

    key = jax.random.PRNGKey(hash(name) % (2**31))
    init_flat = pb.init_flat(key)
    clf_init = cpb.init_flat(jax.random.PRNGKey(1 + hash(name) % (2**31)))

    dims_out = {
        "bs": d.bs, "fanout": d.fanout, "hops": spec.hops,
        "snapshots": d.snapshots, "dm": d.dm, "dh": d.dh, "dv": d.dv,
        "de": d.de, "d_time": d.d_time, "heads": d.heads,
        "mail_slots": d.mail_slots, "maild": d.maild,
        "num_classes": d.num_classes, "n_total": d.n_total,
        "use_memory": 1 if spec.memory is not None else 0,
        "time_proj": 1 if spec.time_proj else 0,
    }
    return {
        "model": cfg["model"],
        "dims": dims_out,
        "param_count": pb.size,
        "clf_param_count": cpb.size,
        "params": pb.manifest(),
        "init_file": f"{name}_params.bin",
        "clf_init_file": f"{name}_clf_params.bin",
        "steps": steps,
        "_hlo_texts": texts,
        "_init": {
            f"{name}_params.bin": init_flat,
            f"{name}_clf_params.bin": clf_init,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="../configs")
    ap.add_argument("--variants", default="all")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    wanted = None if args.variants == "all" else set(args.variants.split(","))

    jobs = [("smoke", None)]
    for path in sorted(glob.glob(os.path.join(args.configs, "*.yml"))):
        name = os.path.splitext(os.path.basename(path))[0]
        with open(path) as fh:
            jobs.append((name, yaml.safe_load(fh)))

    manifest = {"version": 1, "variants": {}}
    for name, cfg in jobs:
        if wanted is not None and name not in wanted and name != "smoke":
            continue
        print(f"[aot] lowering variant `{name}` ...", flush=True)
        v = smoke_variant() if cfg is None else build_variant(name, cfg)
        for fname, text in v.pop("_hlo_texts").items():
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(text)
            print(f"[aot]   wrote {path} ({len(text) / 1e6:.2f} MB)")
        for fname, arr in v.pop("_init").items():
            np.asarray(arr, np.float32).tofile(os.path.join(args.out, fname))
        manifest["variants"][name] = v

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    sys.exit(main())
