"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth (pytest compares kernel outputs
against them) *and* the source of the backward passes (the ``custom_vjp``
backward is ``jax.vjp`` of these functions — exact gradients without
hand-deriving kernel adjoints).
"""

import jax.numpy as jnp


def time_encode_ref(dt, w, phi):
    """Φ(Δt) = cos(Δt ⊗ ω + φ).  dt [...], w [D], phi [D] -> [..., D]."""
    return jnp.cos(dt[..., None] * w + phi)


def attention_ref(q_in, kv_in, mask, wq, wk, wv, heads):
    """Masked multi-head dot-product attention over a fixed neighbor axis.

    q_in  [R, Dq]      root/query representations
    kv_in [R, K, Dk]   neighbor (or mail) representations
    mask  [R, K]       1.0 = valid
    wq [Dq, H*dh], wk/wv [Dk, H*dh]
    returns [R, H*dh]; rows with no valid neighbor return zeros.
    """
    r, k, _ = kv_in.shape
    hd = wq.shape[1]
    dh = hd // heads
    q = (q_in @ wq).reshape(r, heads, dh)
    kk = (kv_in.reshape(r * k, -1) @ wk).reshape(r, k, heads, dh)
    vv = (kv_in.reshape(r * k, -1) @ wv).reshape(r, k, heads, dh)
    scores = jnp.einsum("rhd,rkhd->rhk", q, kk) / jnp.sqrt(jnp.float32(dh))
    neg = jnp.float32(-1e9)
    scores = jnp.where(mask[:, None, :] > 0.0, scores, neg)
    # Stable masked softmax; all-masked rows produce zero context.
    smax = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - smax) * (mask[:, None, :] > 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-9)
    ctx = jnp.einsum("rhk,rkhd->rhd", p, vv)
    return ctx.reshape(r, hd)


def gru_ref(x, h, wi, wh, bi, bh):
    """GRU cell (PyTorch ``GRUCell`` formulation, as in TGN).

    x [N, I], h [N, H], wi [I, 3H], wh [H, 3H], bi/bh [3H] -> [N, H].
    Gate order along the 3H axis: reset | update | new.
    """
    gi = x @ wi + bi
    gh = h @ wh + bh
    hdim = h.shape[1]
    i_r, i_z, i_n = gi[:, :hdim], gi[:, hdim : 2 * hdim], gi[:, 2 * hdim :]
    h_r, h_z, h_n = gh[:, :hdim], gh[:, hdim : 2 * hdim], gh[:, 2 * hdim :]
    r = jnp.clip(1.0 / (1.0 + jnp.exp(-(i_r + h_r))), 0.0, 1.0)
    z = 1.0 / (1.0 + jnp.exp(-(i_z + h_z)))
    n = jnp.tanh(i_n + r * h_n)
    return (1.0 - z) * n + z * h


def rnn_ref(x, h, wi, wh, b):
    """Vanilla RNN cell (JODIE's updater): tanh(x Wi + h Wh + b)."""
    return jnp.tanh(x @ wi + h @ wh + b)
