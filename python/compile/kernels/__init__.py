"""Layer 1: Pallas kernels for the TGNN compute hot-spots.

Three kernels cover the unified TGNN component set (paper §2):

- :mod:`.time_encode` — the learnable time encoder Φ(Δt) = cos(ωΔt + φ)
  (Eq. 3), used by every variant.
- :mod:`.attention`   — masked multi-head temporal attention over K sampled
  neighbors (the attention aggregator, §2.2) and over mailbox slots
  (APAN's COMB).
- :mod:`.gru`         — the GRU / RNN memory updater UPDT (Eq. 4).

Each kernel ships as ``<name>_op``: a ``jax.custom_vjp`` whose forward is
the Pallas kernel (``interpret=True`` — CPU PJRT cannot run Mosaic
custom-calls; see DESIGN.md §Hardware-Adaptation) and whose backward is
derived from the pure-jnp oracle in :mod:`.ref` via ``jax.vjp`` —
mathematically exact, rematerializing, and verified against finite
differences in the test suite.
"""

from .attention import attention_op, attention_pallas
from .gru import gru_op, gru_pallas, rnn_op, rnn_pallas
from .time_encode import time_encode_op, time_encode_pallas

__all__ = [
    "attention_op",
    "attention_pallas",
    "gru_op",
    "gru_pallas",
    "rnn_op",
    "rnn_pallas",
    "time_encode_op",
    "time_encode_pallas",
]
