"""Pallas kernels: the memory updaters UPDT (paper Eq. 4).

- ``gru_op``  — GRU cell (TGN, APAN): two fused [BLOCK_N, I|H] × [., 3H]
  projections plus the gate nonlinearities, one block of nodes at a time.
- ``rnn_op``  — vanilla RNN cell (JODIE).

Both keep the whole gate computation in VMEM per block; the MXU sees two
(BLOCK_N × I) @ (I × 3H) matmuls per block — at BLOCK_N = 128, I ≈ 400,
H = 100 that is ≈ 0.6 MB of operand tiles (DESIGN.md §Perf).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_N = 128


def _gru_kernel(x_ref, h_ref, wi_ref, wh_ref, bi_ref, bh_ref, o_ref):
    x = x_ref[...]
    h = h_ref[...]
    gi = x @ wi_ref[...] + bi_ref[...][None, :]
    gh = h @ wh_ref[...] + bh_ref[...][None, :]
    hdim = h.shape[1]
    i_r, i_z, i_n = gi[:, :hdim], gi[:, hdim : 2 * hdim], gi[:, 2 * hdim :]
    h_r, h_z, h_n = gh[:, :hdim], gh[:, hdim : 2 * hdim], gh[:, 2 * hdim :]
    r = 1.0 / (1.0 + jnp.exp(-(i_r + h_r)))
    z = 1.0 / (1.0 + jnp.exp(-(i_z + h_z)))
    n = jnp.tanh(i_n + r * h_n)
    o_ref[...] = (1.0 - z) * n + z * h


def _rnn_kernel(x_ref, h_ref, wi_ref, wh_ref, b_ref, o_ref):
    o_ref[...] = jnp.tanh(
        x_ref[...] @ wi_ref[...] + h_ref[...] @ wh_ref[...] + b_ref[...][None, :]
    )


def _blocked_cell(kernel, x, h, weights, out_dim):
    n = x.shape[0]
    n_pad = pl.cdiv(max(n, 1), BLOCK_N) * BLOCK_N
    x_p = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    h_p = jnp.pad(h, ((0, n_pad - n), (0, 0)))
    in_specs = [
        pl.BlockSpec((BLOCK_N, x.shape[1]), lambda i: (i, 0)),
        pl.BlockSpec((BLOCK_N, h.shape[1]), lambda i: (i, 0)),
    ] + [
        # nd bound eagerly (late-binding closures would all see the last w).
        pl.BlockSpec(w.shape, lambda i, nd=len(w.shape): (0,) * nd)
        for w in weights
    ]
    out = pl.pallas_call(
        kernel,
        grid=(n_pad // BLOCK_N,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BLOCK_N, out_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, out_dim), jnp.float32),
        interpret=True,
    )(x_p, h_p, *weights)
    return out[:n]


def gru_pallas(x, h, wi, wh, bi, bh):
    """x [N, I], h [N, H] -> new h [N, H]."""
    return _blocked_cell(_gru_kernel, x, h, (wi, wh, bi, bh), h.shape[1])


def rnn_pallas(x, h, wi, wh, b):
    """x [N, I], h [N, H] -> new h [N, H]."""
    return _blocked_cell(_rnn_kernel, x, h, (wi, wh, b), h.shape[1])


@jax.custom_vjp
def gru_op(x, h, wi, wh, bi, bh):
    """Differentiable GRU cell: Pallas forward, oracle-derived backward."""
    return gru_pallas(x, h, wi, wh, bi, bh)


def _gru_fwd(x, h, wi, wh, bi, bh):
    return gru_pallas(x, h, wi, wh, bi, bh), (x, h, wi, wh, bi, bh)


def _gru_bwd(res, g):
    _, vjp = jax.vjp(ref.gru_ref, *res)
    return vjp(g)


gru_op.defvjp(_gru_fwd, _gru_bwd)


@jax.custom_vjp
def rnn_op(x, h, wi, wh, b):
    """Differentiable RNN cell: Pallas forward, oracle-derived backward."""
    return rnn_pallas(x, h, wi, wh, b)


def _rnn_fwd(x, h, wi, wh, b):
    return rnn_pallas(x, h, wi, wh, b), (x, h, wi, wh, b)


def _rnn_bwd(res, g):
    _, vjp = jax.vjp(ref.rnn_ref, *res)
    return vjp(g)


rnn_op.defvjp(_rnn_fwd, _rnn_bwd)
