"""Pallas kernel: the learnable time encoder Φ(Δt) = cos(ωΔt + φ) (Eq. 3).

Tiny but ubiquitous — every attention call and every memory refresh feeds
time deltas through it, so it is fused as one VMEM-resident block per
``BLOCK_N`` deltas. The TPU BlockSpec maps the Δt vector into VMEM in
(BLOCK_N,) strips while ω/φ stay resident; the output tile is
(BLOCK_N, D) — all well under VMEM for D ≤ 512 (see DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_N = 256


def _kernel(dt_ref, w_ref, phi_ref, o_ref):
    dt = dt_ref[...]
    o_ref[...] = jnp.cos(dt[:, None] * w_ref[...][None, :] + phi_ref[...][None, :])


def time_encode_pallas(dt, w, phi):
    """Φ over a flat batch of deltas: dt [N], w [D], phi [D] -> [N, D]."""
    n = dt.shape[0]
    d = w.shape[0]
    n_pad = pl.cdiv(n, BLOCK_N) * BLOCK_N
    dt_p = jnp.pad(dt, (0, n_pad - n))
    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=True,
    )(dt_p, w, phi)
    return out[:n]


@jax.custom_vjp
def time_encode_op(dt, w, phi):
    """Differentiable Φ: Pallas forward, oracle-derived backward."""
    return time_encode_pallas(dt, w, phi)


def _fwd(dt, w, phi):
    return time_encode_pallas(dt, w, phi), (dt, w, phi)


def _bwd(res, g):
    _, vjp = jax.vjp(ref.time_encode_ref, *res)
    return vjp(g)


time_encode_op.defvjp(_fwd, _bwd)


@functools.partial(jax.jit, static_argnums=())
def _noop():  # pragma: no cover - keeps module import side-effect free
    return jnp.zeros(())
