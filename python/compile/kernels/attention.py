"""Pallas kernel: masked multi-head temporal attention (paper §2.2).

One fused kernel computes, per block of BLOCK_R roots: the Q/K/V
projections, the per-head scaled dot-product scores over the K sampled
neighbors, the masked stable softmax, and the context reduction — the
entire attention aggregator without materializing [R, H, K] score tensors
in HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the neighbor axis K (10) and
head dim are small, so the MXU work is the two [BLOCK_R·K, Dk] × [Dk, HD]
projections; BLOCK_R = 128 keeps q/k/v tiles plus the (BLOCK_R, H, K)
score tile comfortably inside VMEM (≈ (128·10·Dk + Dk·HD + 128·HD)·4 B ≈
2–3 MB at Dk ≈ 300, HD = 100). What CUDA implementations express with one
threadblock per root row becomes the grid dimension over root blocks.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_R = 128


def _kernel(heads, q_ref, kv_ref, mask_ref, wq_ref, wk_ref, wv_ref, o_ref):
    br, k, dk = kv_ref.shape
    hd = wq_ref.shape[1]
    dh = hd // heads
    q = (q_ref[...] @ wq_ref[...]).reshape(br, heads, dh)
    kv = kv_ref[...].reshape(br * k, dk)
    kk = (kv @ wk_ref[...]).reshape(br, k, heads, dh)
    vv = (kv @ wv_ref[...]).reshape(br, k, heads, dh)
    scores = jnp.einsum("rhd,rkhd->rhk", q, kk) / jnp.sqrt(jnp.float32(dh))
    valid = mask_ref[...][:, None, :] > 0.0
    scores = jnp.where(valid, scores, jnp.float32(-1e9))
    smax = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - smax) * valid
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-9)
    ctx = jnp.einsum("rhk,rkhd->rhd", p / denom, vv)
    o_ref[...] = ctx.reshape(br, hd)


def attention_pallas(q_in, kv_in, mask, wq, wk, wv, heads):
    """q_in [R, Dq], kv_in [R, K, Dk], mask [R, K] -> [R, H*dh]."""
    r, k, dk = kv_in.shape
    dq = q_in.shape[1]
    hd = wq.shape[1]
    r_pad = pl.cdiv(max(r, 1), BLOCK_R) * BLOCK_R
    q_p = jnp.pad(q_in, ((0, r_pad - r), (0, 0)))
    kv_p = jnp.pad(kv_in, ((0, r_pad - r), (0, 0), (0, 0)))
    mask_p = jnp.pad(mask, ((0, r_pad - r), (0, 0)))
    import functools

    out = pl.pallas_call(
        functools.partial(_kernel, heads),
        grid=(r_pad // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, dq), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, k, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLOCK_R, k), lambda i: (i, 0)),
            pl.BlockSpec((dq, hd), lambda i: (0, 0)),
            pl.BlockSpec((dk, hd), lambda i: (0, 0)),
            pl.BlockSpec((dk, hd), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, hd), jnp.float32),
        interpret=True,
    )(q_p, kv_p, mask_p, wq, wk, wv)
    return out[:r]


def attention_op(q_in, kv_in, mask, wq, wk, wv, heads):
    """Differentiable attention: Pallas forward, oracle-derived backward.

    ``heads`` is static; a per-head-count custom_vjp is cached.
    """
    return _ops(heads)(q_in, kv_in, mask, wq, wk, wv)


_CACHE = {}


def _ops(heads):
    if heads in _CACHE:
        return _CACHE[heads]

    @jax.custom_vjp
    def op(q_in, kv_in, mask, wq, wk, wv):
        return attention_pallas(q_in, kv_in, mask, wq, wk, wv, heads)

    def fwd(q_in, kv_in, mask, wq, wk, wv):
        return op(q_in, kv_in, mask, wq, wk, wv), (q_in, kv_in, mask, wq, wk, wv)

    def bwd(res, g):
        q_in, kv_in, mask, wq, wk, wv = res
        _, vjp = jax.vjp(
            lambda q, kv, m, a, b, c: ref.attention_ref(q, kv, m, a, b, c, heads),
            q_in,
            kv_in,
            mask,
            wq,
            wk,
            wv,
        )
        return vjp(g)

    op.defvjp(fwd, bwd)
    _CACHE[heads] = op
    return op
