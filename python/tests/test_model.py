"""Model-zoo step functions: shapes, gradient flow, loss behavior.

These run the *python* callables (pre-lowering); the lowered HLO is
exercised end-to-end by the Rust integration tests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def tiny_dims(spec: M.Spec) -> M.Dims:
    return M.Dims(
        bs=8, fanout=3, hops=spec.hops, snapshots=spec.snapshots,
        dm=12, dh=12, dv=10, de=6, d_time=8, heads=2,
        mail_slots=spec.mail_slots, num_classes=3,
    )


def example_inputs(spec: M.Spec, d: M.Dims, pb: M.ParamBuilder, ins, seed=0):
    key = jax.random.PRNGKey(seed)
    args = []
    for name, shape in ins:
        key, sub = jax.random.split(key)
        if name in ("params", "adam_m", "adam_v"):
            if name == "params":
                args.append(jnp.asarray(pb.init_flat(sub)))
            else:
                args.append(jnp.zeros(pb.size, jnp.float32))
        elif name == "step":
            args.append(jnp.zeros((), jnp.float32))
        elif name == "lr":
            args.append(jnp.float32(1e-2))
        elif name == "dt_scale":
            args.append(jnp.float32(1e-3))
        elif name == "edge_mask" or name.startswith("mask") or name == "mail_mask":
            args.append((jax.random.uniform(sub, shape) > 0.2).astype(jnp.float32))
        elif "dt" in name:
            args.append(jnp.abs(jax.random.normal(sub, shape)) * 10)
        else:
            args.append(jax.random.normal(sub, shape, jnp.float32) * 0.3)
    return args


@pytest.mark.parametrize("variant", ["tgn", "tgat", "jodie", "apan", "dysat"])
def test_train_step_shapes_and_finite(variant):
    base = M.SPECS[variant]
    d = tiny_dims(base)
    spec = M.Spec(variant, base.memory, base.hops, d.snapshots, d.mail_slots, base.time_proj)
    pb = M.build_params(spec, d)
    train_step, train_ins, eval_step, eval_ins = M.make_steps(spec, d, pb)
    args = example_inputs(spec, d, pb, train_ins)
    out = jax.jit(train_step)(*args)
    assert np.isfinite(float(out["loss"]))
    assert out["new_params"].shape == (pb.size,)
    assert np.all(np.isfinite(np.asarray(out["new_params"])))
    if spec.memory is not None:
        assert out["new_mem"].shape == (d.n_total, d.dm)
        assert out["new_mail"].shape == (2 * d.bs, d.maild)

    # Eval: scores + embeddings.
    eargs = example_inputs(spec, d, pb, eval_ins, seed=1)
    eout = jax.jit(eval_step)(*eargs)
    assert eout["pos_score"].shape == (d.bs,)
    assert eout["emb"].shape == (d.b0, d.dh)
    assert np.all(np.isfinite(np.asarray(eout["emb"])))


@pytest.mark.parametrize("variant", ["tgn", "tgat"])
def test_adam_reduces_loss_on_fixed_batch(variant):
    base = M.SPECS[variant]
    d = tiny_dims(base)
    spec = M.Spec(variant, base.memory, base.hops, d.snapshots, d.mail_slots, base.time_proj)
    pb = M.build_params(spec, d)
    train_step, train_ins, _, _ = M.make_steps(spec, d, pb)
    args = example_inputs(spec, d, pb, train_ins)
    jitted = jax.jit(train_step)
    names = [n for n, _ in train_ins]
    idx = {n: i for i, n in enumerate(names)}
    losses = []
    for it in range(30):
        out = jitted(*args)
        losses.append(float(out["loss"]))
        args[idx["params"]] = out["new_params"]
        args[idx["adam_m"]] = out["new_adam_m"]
        args[idx["adam_v"]] = out["new_adam_v"]
        args[idx["step"]] = args[idx["step"]] + 1.0
    assert losses[-1] < losses[0] * 0.8, f"no learning: {losses[0]:.4f} -> {losses[-1]:.4f}"


def test_memory_identity_without_mail():
    base = M.SPECS["tgn"]
    d = tiny_dims(base)
    spec = M.Spec("tgn", base.memory, base.hops, d.snapshots, d.mail_slots, base.time_proj)
    pb = M.build_params(spec, d)
    P = pb.unpacker()(jnp.asarray(pb.init_flat(jax.random.PRNGKey(0))))
    n = 5
    mem = jax.random.normal(jax.random.PRNGKey(1), (n, d.dm), jnp.float32)
    mail = jnp.zeros((n, 1, d.maild))
    mail_dt = jnp.zeros((n, 1))
    mail_mask = jnp.zeros((n, 1))
    out = M.refresh_memory(spec, d, P, mem, mail, mail_dt, mail_mask)
    np.testing.assert_allclose(out, mem, rtol=1e-6)
    # With mail present the memory must change.
    out2 = M.refresh_memory(spec, d, P, mem, mail, mail_dt, mail_mask.at[0, 0].set(1.0))
    assert not np.allclose(out2[0], mem[0])
    np.testing.assert_allclose(out2[1:], mem[1:], rtol=1e-6)


def test_edge_mask_controls_loss():
    base = M.SPECS["tgat"]
    d = tiny_dims(base)
    spec = M.Spec("tgat", base.memory, base.hops, d.snapshots, d.mail_slots, base.time_proj)
    pb = M.build_params(spec, d)
    _, _, eval_step, eval_ins = M.make_steps(spec, d, pb)
    args = example_inputs(spec, d, pb, eval_ins)
    names = [n for n, _ in eval_ins]
    idx = {n: i for i, n in enumerate(names)}
    # Loss with all edges masked off the first half vs full: must differ
    # only through the kept edges.
    args[idx["edge_mask"]] = jnp.ones(d.bs)
    full = jax.jit(eval_step)(*args)
    args[idx["edge_mask"]] = jnp.zeros(d.bs).at[0].set(1.0)
    single = jax.jit(eval_step)(*args)
    pos0 = float(full["pos_score"][0])
    exp = float(np.logaddexp(0.0, -pos0) + np.logaddexp(0.0, float(full["neg_score"][0])))
    assert abs(float(single["loss"]) - exp) < 1e-5


def test_clf_step_learns():
    d = M.Dims(bs=16, dh=12, num_classes=3)
    pb = M.clf_param_builder(d)
    clf_step, _ = M.make_clf_step(d, pb)
    key = jax.random.PRNGKey(0)
    emb = jax.random.normal(key, (d.bs, d.dh), jnp.float32)
    labels = jnp.asarray(np.arange(16) % 3, jnp.int32)
    mask = jnp.ones(16)
    params = jnp.asarray(pb.init_flat(key))
    m = jnp.zeros(pb.size)
    v = jnp.zeros(pb.size)
    jitted = jax.jit(clf_step)
    first = None
    for it in range(60):
        out = jitted(params, m, v, jnp.float32(it), jnp.float32(0.05), emb, labels, mask)
        if first is None:
            first = float(out["loss"])
        params, m, v = out["new_params"], out["new_adam_m"], out["new_adam_v"]
    assert float(out["loss"]) < first * 0.5
    assert out["logits"].shape == (16, 3)


def test_dims_layout_matches_all_nodes_convention():
    # n_total and hop offsets must enumerate roots, then (snapshot, hop)
    # blocks in order — the Mfg::all_nodes contract.
    d = M.Dims(bs=2, fanout=3, hops=2, snapshots=2)
    b0 = 6
    l1 = b0 * 3
    l2 = b0 * 9
    assert d.b0 == b0
    assert d.n_total == b0 + 2 * (l1 + l2)
    assert d.hop_offset(0, 0) == b0
    assert d.hop_offset(0, 1) == b0 + l1
    assert d.hop_offset(1, 0) == b0 + l1 + l2
    assert d.hop_offset(1, 1) == b0 + l1 + l2 + l1
