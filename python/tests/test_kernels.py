"""Pallas kernels vs pure-jnp oracles: values and gradients.

Hypothesis sweeps shapes; tolerances are tight because interpret-mode
Pallas and XLA execute the same float32 math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention_op,
    attention_pallas,
    gru_op,
    gru_pallas,
    rnn_op,
    rnn_pallas,
    time_encode_op,
    time_encode_pallas,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32) * 0.5


# ---------------------------------------------------------------- time enc


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 700), d=st.integers(1, 130))
def test_time_encode_matches_ref(n, d):
    k = jax.random.split(jax.random.PRNGKey(n * 1000 + d), 3)
    dt = jnp.abs(rand(k[0], n)) * 100
    w, phi = rand(k[1], d), rand(k[2], d)
    got = time_encode_pallas(dt, w, phi)
    want = ref.time_encode_ref(dt, w, phi)
    # cos() of O(100) arguments amplifies ulp-level differences between the
    # two compilation paths; 1e-4 absolute is tight for f32 there.
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_time_encode_grads():
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    dt, w, phi = jnp.abs(rand(k[0], 37)), rand(k[1], 11), rand(k[2], 11)

    def f_op(dt, w, phi):
        return jnp.sum(time_encode_op(dt, w, phi) ** 2)

    def f_ref(dt, w, phi):
        return jnp.sum(ref.time_encode_ref(dt, w, phi) ** 2)

    g_op = jax.grad(f_op, argnums=(0, 1, 2))(dt, w, phi)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(dt, w, phi)
    for a, b in zip(g_op, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- attention


@settings(max_examples=6, deadline=None)
@given(
    r=st.integers(1, 300),
    k=st.integers(1, 12),
    heads=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 20]),
)
def test_attention_matches_ref(r, k, heads, dh):
    keys = jax.random.split(jax.random.PRNGKey(r * 31 + k), 6)
    dq, dk = 13, 17
    hd = heads * dh
    q = rand(keys[0], r, dq)
    kv = rand(keys[1], r, k, dk)
    mask = (jax.random.uniform(keys[2], (r, k)) > 0.3).astype(jnp.float32)
    wq, wk, wv = rand(keys[3], dq, hd), rand(keys[4], dk, hd), rand(keys[5], dk, hd)
    got = attention_pallas(q, kv, mask, wq, wk, wv, heads)
    want = ref.attention_ref(q, kv, mask, wq, wk, wv, heads)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_all_masked_row_is_zero():
    keys = jax.random.split(jax.random.PRNGKey(5), 6)
    q = rand(keys[0], 4, 6)
    kv = rand(keys[1], 4, 5, 7)
    mask = jnp.zeros((4, 5)).at[0].set(1.0)
    wq, wk, wv = rand(keys[2], 6, 8), rand(keys[3], 7, 8), rand(keys[4], 7, 8)
    out = attention_pallas(q, kv, mask, wq, wk, wv, 2)
    assert jnp.all(out[1:] == 0.0)
    assert jnp.any(out[0] != 0.0)


def test_attention_grads_match_ref():
    keys = jax.random.split(jax.random.PRNGKey(7), 6)
    r, k, heads, dh = 9, 4, 2, 6
    hd = heads * dh
    q = rand(keys[0], r, 5)
    kv = rand(keys[1], r, k, 8)
    mask = (jax.random.uniform(keys[2], (r, k)) > 0.4).astype(jnp.float32)
    wq, wk, wv = rand(keys[3], 5, hd), rand(keys[4], 8, hd), rand(keys[5], 8, hd)

    def f_op(q, wq, wk, wv):
        return jnp.sum(attention_op(q, kv, mask, wq, wk, wv, heads) ** 2)

    def f_ref(q, wq, wk, wv):
        return jnp.sum(ref.attention_ref(q, kv, mask, wq, wk, wv, heads) ** 2)

    g_op = jax.grad(f_op, argnums=(0, 1, 2, 3))(q, wq, wk, wv)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2, 3))(q, wq, wk, wv)
    for a, b in zip(g_op, g_ref):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- gru / rnn


@settings(max_examples=6, deadline=None)
@given(n=st.integers(1, 300), i=st.integers(1, 40), h=st.integers(1, 40))
def test_gru_matches_ref(n, i, h):
    keys = jax.random.split(jax.random.PRNGKey(n + i * 7 + h * 13), 6)
    x, hh = rand(keys[0], n, i), rand(keys[1], n, h)
    wi, wh = rand(keys[2], i, 3 * h), rand(keys[3], h, 3 * h)
    bi, bh = rand(keys[4], 3 * h), rand(keys[5], 3 * h)
    got = gru_pallas(x, hh, wi, wh, bi, bh)
    want = ref.gru_ref(x, hh, wi, wh, bi, bh)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=5, deadline=None)
@given(n=st.integers(1, 200), i=st.integers(1, 30), h=st.integers(1, 30))
def test_rnn_matches_ref(n, i, h):
    keys = jax.random.split(jax.random.PRNGKey(n * 3 + i + h), 5)
    x, hh = rand(keys[0], n, i), rand(keys[1], n, h)
    wi, wh, b = rand(keys[2], i, h), rand(keys[3], h, h), rand(keys[4], h)
    got = rnn_pallas(x, hh, wi, wh, b)
    want = ref.rnn_ref(x, hh, wi, wh, b)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_gru_gates_bound_state():
    # GRU output must interpolate between n (tanh-bounded) and previous h.
    keys = jax.random.split(jax.random.PRNGKey(3), 6)
    n, i, h = 64, 12, 8
    x, hh = rand(keys[0], n, i), jnp.clip(rand(keys[1], n, h), -1, 1)
    wi, wh = rand(keys[2], i, 3 * h), rand(keys[3], h, 3 * h)
    bi, bh = rand(keys[4], 3 * h), rand(keys[5], 3 * h)
    out = gru_pallas(x, hh, wi, wh, bi, bh)
    assert jnp.all(jnp.abs(out) <= 1.0 + 1e-6)


def test_gru_rnn_grads_match_ref():
    keys = jax.random.split(jax.random.PRNGKey(11), 6)
    n, i, h = 17, 6, 5
    x, hh = rand(keys[0], n, i), rand(keys[1], n, h)
    wi, wh = rand(keys[2], i, 3 * h), rand(keys[3], h, 3 * h)
    bi, bh = rand(keys[4], 3 * h), rand(keys[5], 3 * h)

    g_op = jax.grad(lambda *a: jnp.sum(gru_op(*a) ** 2), argnums=tuple(range(6)))(
        x, hh, wi, wh, bi, bh
    )
    g_ref = jax.grad(lambda *a: jnp.sum(ref.gru_ref(*a) ** 2), argnums=tuple(range(6)))(
        x, hh, wi, wh, bi, bh
    )
    for a, b in zip(g_op, g_ref):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    wi2, wh2, b2 = rand(keys[2], i, h), rand(keys[3], h, h), rand(keys[4], h)
    g_op = jax.grad(lambda *a: jnp.sum(rnn_op(*a) ** 2), argnums=tuple(range(5)))(
        x, hh, wi2, wh2, b2
    )
    g_ref = jax.grad(lambda *a: jnp.sum(ref.rnn_ref(*a) ** 2), argnums=tuple(range(5)))(
        x, hh, wi2, wh2, b2
    )
    for a, b in zip(g_op, g_ref):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
