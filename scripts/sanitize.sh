#!/usr/bin/env bash
# Dynamic-analysis sweep: ThreadSanitizer and Miri over the concurrency
# and unsafe-code surface that pallas-lint can only check structurally.
#
# Usage: scripts/sanitize.sh [--tsan-only] [--miri-only]
#
# Both analyses need a nightly toolchain (`-Z sanitizer` / `cargo miri`),
# which the minimal CI containers do not carry, so this script is
# ADVISORY by default: a missing nightly (or missing component) skips
# that analysis with a warning and does not fail the run. Actual TSan /
# Miri findings DO fail (exit 1) — run it on a dev box or the nightly CI
# lane to get the hard signal. Set TGL_SANITIZE_STRICT=1 to also fail
# when the toolchain is missing (for the lane that is supposed to have it).
#
# Scope (matches the lint rules it complements):
#   TSan : pipeline_identity (sharded + the batch-tiled exec sweep) +
#          fault_tolerance + the pool unit tests — the fork-join pool,
#          supervised producers, shard workers, and the tile-parallel
#          forward/backward (disjoint-slice raw pointers) are where a
#          lock-order or raw-pointer mistake becomes a race.
#   Miri : pool + simd unit tests — the two modules with `unsafe`
#          (lifetime-erased job dispatch, disjoint-chunk slice splits).
set -uo pipefail
cd "$(dirname "$0")/.."

RUN_TSAN=1
RUN_MIRI=1
for arg in "$@"; do
  case "$arg" in
    --tsan-only) RUN_MIRI=0 ;;
    --miri-only) RUN_TSAN=0 ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

STRICT="${TGL_SANITIZE_STRICT:-0}"
FAILED=0
SKIPPED=0

skip() {
  echo "sanitize: SKIP — $1" >&2
  SKIPPED=1
  if [ "$STRICT" = 1 ]; then
    FAILED=1
  fi
}

if ! command -v cargo >/dev/null 2>&1; then
  skip "cargo not found on PATH"
  [ "$FAILED" = 1 ] && exit 1
  echo "sanitize: nothing run (advisory)"
  exit 0
fi

# Nightly detection: an installed `+nightly` toolchain, or the default
# toolchain already being nightly.
NIGHTLY=""
if cargo +nightly --version >/dev/null 2>&1; then
  NIGHTLY="+nightly"
elif cargo --version 2>/dev/null | grep -q nightly; then
  NIGHTLY=""
else
  skip "no nightly toolchain (rustup toolchain install nightly)"
  [ "$FAILED" = 1 ] && exit 1
  echo "sanitize: nothing run (advisory)"
  exit 0
fi

HOST_TARGET="$(rustc ${NIGHTLY:+$NIGHTLY} -vV 2>/dev/null | sed -n 's/^host: //p')"

if [ "$RUN_TSAN" = 1 ]; then
  if [ -z "$HOST_TARGET" ]; then
    skip "could not determine host target for TSan"
  else
    echo "== sanitize: ThreadSanitizer (target $HOST_TARGET) =="
    # TSan needs std rebuilt with the sanitizer (-Z build-std + rust-src).
    TSAN_OK=1
    for spec in "--test pipeline_identity sharded" "--test pipeline_identity exec_tiles" \
        "--test fault_tolerance" "--lib util::pool"; do
      echo "-- tsan: cargo test $spec"
      # shellcheck disable=SC2086  # spec is a word list on purpose
      if ! RUSTFLAGS="-Z sanitizer=thread" cargo $NIGHTLY test -Z build-std \
          --target "$HOST_TARGET" -q $spec; then
        TSAN_OK=0
      fi
    done
    if [ "$TSAN_OK" = 1 ]; then
      echo "sanitize: TSan clean"
    else
      echo "sanitize: TSan FAILED (race or build failure above)" >&2
      FAILED=1
    fi
  fi
fi

if [ "$RUN_MIRI" = 1 ]; then
  if cargo $NIGHTLY miri --version >/dev/null 2>&1; then
    echo "== sanitize: Miri (pool + simd unit tests) =="
    # Miri is slow; keep it to the unsafe-bearing modules.
    if MIRIFLAGS="-Zmiri-disable-isolation" \
        cargo $NIGHTLY miri test -q --lib util::pool runtime::simd 2>&1 | tail -20; then
      echo "sanitize: Miri clean"
    else
      echo "sanitize: Miri FAILED (undefined behaviour above)" >&2
      FAILED=1
    fi
  else
    skip "miri component not installed (rustup component add miri --toolchain nightly)"
  fi
fi

if [ "$FAILED" = 1 ]; then
  echo "sanitize: FAILED"
  exit 1
fi
if [ "$SKIPPED" = 1 ]; then
  echo "sanitize: OK (with skips — advisory mode)"
else
  echo "sanitize: OK"
fi
