#!/usr/bin/env bash
# Compare a fresh BENCH_pipeline.json against the committed baseline and
# fail on perf regressions.
#
# Usage:
#   scripts/bench_compare.sh [--update] [--tolerance PCT] [--fresh PATH]
#
#   --update          copy the fresh results over the baseline (seeding or
#                     intentionally re-baselining after a verified change)
#   --tolerance PCT   allowed relative regression, percent (default 10)
#   --fresh PATH      fresh results file (default ./BENCH_pipeline.json,
#                     produced by `cargo bench --bench training`)
#   --check-only      no report, exit code only: 0 within tolerance (or
#                     bootstrap), 1 regression, 2 usage error. For CI
#                     wiring where the caller owns the output.
#
# Rows are matched on (workload, mode). Only the dimensionless `speedup`
# field is compared — absolute seconds vary across machines, but the
# arena/prefetch speedup ratios are what the perf work actually claims,
# and a >tolerance drop in any of them fails the script (exit 1).
#
# Bootstrap: if no baseline is committed yet, the script reports what it
# would compare and exits 0 with instructions (first toolchain-bearing CI
# run seeds it via --update).
set -euo pipefail
cd "$(dirname "$0")/.."

FRESH="BENCH_pipeline.json"
BASELINE="benches/baseline/BENCH_pipeline.json"
TOLERANCE=10
UPDATE=0
CHECK_ONLY=0
while [ $# -gt 0 ]; do
  case "$1" in
    --update) UPDATE=1 ;;
    --tolerance) shift; TOLERANCE="${1:?--tolerance needs a value}" ;;
    --fresh) shift; FRESH="${1:?--fresh needs a path}" ;;
    --check-only) CHECK_ONLY=1 ;;
    *) echo "unknown flag: $1" >&2; exit 2 ;;
  esac
  shift
done

if [ "$CHECK_ONLY" = 1 ] && [ "$UPDATE" = 1 ]; then
  echo "bench_compare: --check-only and --update are mutually exclusive" >&2
  exit 2
fi
if [ "$CHECK_ONLY" = 1 ]; then
  # exit code only: rerun without the flag when you want the report
  exec >/dev/null
fi

if [ ! -f "$FRESH" ]; then
  echo "bench_compare: no fresh results at $FRESH — run \`cargo bench --bench training\` first" >&2
  exit 2
fi

if [ "$UPDATE" = 1 ]; then
  mkdir -p "$(dirname "$BASELINE")"
  cp "$FRESH" "$BASELINE"
  echo "bench_compare: baseline updated from $FRESH"
  exit 0
fi

if [ ! -f "$BASELINE" ]; then
  echo "bench_compare: no committed baseline at $BASELINE yet."
  echo "Seed it from a trusted run with: scripts/bench_compare.sh --update"
  python3 - "$FRESH" <<'EOF'
import json, sys
rows = json.load(open(sys.argv[1])).get("rows", [])
print("fresh rows that will be tracked once a baseline exists:")
for r in rows:
    if "speedup" in r:
        print(f"  {r.get('workload')}/{r.get('mode')}: speedup {r['speedup']:.3f}x")
EOF
  exit 0
fi

python3 - "$FRESH" "$BASELINE" "$TOLERANCE" <<'EOF'
import json, sys

fresh_path, base_path, tol_pct = sys.argv[1], sys.argv[2], float(sys.argv[3])
fresh = {(r.get("workload"), r.get("mode")): r
         for r in json.load(open(fresh_path)).get("rows", [])}
base = {(r.get("workload"), r.get("mode")): r
        for r in json.load(open(base_path)).get("rows", [])}

failures, compared, new_rows = [], 0, 0
for key, b in sorted(base.items()):
    if "speedup" not in b:
        continue
    f = fresh.get(key)
    if f is None:
        failures.append(f"{key[0]}/{key[1]}: row missing from fresh results")
        continue
    if "speedup" not in f:
        failures.append(f"{key[0]}/{key[1]}: fresh row lost its speedup field")
        continue
    compared += 1
    b_s, f_s = b["speedup"], f["speedup"]
    drop = (b_s - f_s) / b_s * 100.0 if b_s > 0 else 0.0
    status = "OK"
    if drop > tol_pct:
        status = "REGRESSION"
        failures.append(
            f"{key[0]}/{key[1]}: speedup {b_s:.3f}x -> {f_s:.3f}x ({drop:.1f}% drop)")
    print(f"  [{status}] {key[0]}/{key[1]}: baseline {b_s:.3f}x, fresh {f_s:.3f}x")

# Newly-added bench rows with no committed baseline yet are informational,
# not an error: they start being gated after the next `--update`.
for key in sorted(fresh.keys()):
    f = fresh[key]
    if key not in base and "speedup" in f:
        new_rows += 1
        print(f"  [NEW] {key[0]}/{key[1]}: speedup {f['speedup']:.3f}x "
              f"(absent from baseline; tracked after --update)")

print(f"bench_compare: {compared} rows compared, {new_rows} new, tolerance {tol_pct:.0f}%")
if failures:
    print("bench_compare: FAILED")
    for msg in failures:
        print(f"  - {msg}")
    sys.exit(1)
print("bench_compare: OK")
EOF
