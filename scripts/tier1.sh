#!/usr/bin/env bash
# Tier-1 verification: build, tests, formatting, lints.
#
# Usage: scripts/tier1.sh [--no-clippy] [--no-fmt]
# Mirrors ROADMAP.md's "Tier-1 verify" contract plus the fmt/clippy gates;
# CI and pre-PR checks should both run this script.
set -euo pipefail
cd "$(dirname "$0")/.."

RUN_CLIPPY=1
RUN_FMT=1
for arg in "$@"; do
  case "$arg" in
    --no-clippy) RUN_CLIPPY=0 ;;
    --no-fmt) RUN_FMT=0 ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

# Static-analysis gate first: pallas-lint needs no Rust toolchain (plain
# python3), runs in well under two seconds, and catches panic-surface /
# alloc-region / lock-order / cast / CRC violations before any compile.
# Set TGL_LINT_ADVISORY=1 to downgrade to a warning (mirrors the fmt gate).
if command -v python3 >/dev/null 2>&1; then
  if [ "${TGL_LINT_ADVISORY:-0}" = 1 ]; then
    echo "== tier1: pallas-lint (advisory via TGL_LINT_ADVISORY=1) =="
    python3 tools/lint/pallas_lint.py || echo "tier1: WARNING — lint violations (advisory)" >&2
    echo "== tier1: pallas-lint self-tests (advisory) =="
    python3 tools/lint/tests/test_lint.py \
      || echo "tier1: WARNING — lint self-tests failed (advisory)" >&2
  else
    echo "== tier1: pallas-lint =="
    python3 tools/lint/pallas_lint.py
    echo "== tier1: pallas-lint self-tests =="
    python3 tools/lint/tests/test_lint.py
  fi
else
  echo "tier1: python3 unavailable, skipping pallas-lint gate" >&2
fi

if ! command -v cargo >/dev/null 2>&1; then
  echo "tier1: cargo not found on PATH — install a Rust toolchain first" >&2
  exit 3
fi

echo "== tier1: cargo build --release =="
cargo build --release

# Learning-dynamics gate first: a regression in the reference backend's
# training math (loss no longer decreasing, AP at chance) fails fast and
# visibly here, before the full suite buries it.
echo "== tier1: cargo test -q --test convergence =="
cargo test -q --test convergence

# Sharded-pipeline identity sweep by name: shards ∈ {1,2,4} must be
# bitwise-identical to the flat single-producer pipeline across worker
# counts and queue depths (single trainer, multi trainer, nodeclf).
echo "== tier1: cargo test -q --test pipeline_identity sharded =="
cargo test -q --test pipeline_identity sharded

# Batch-blocked executor identity by name: exec tiles = 1 bitwise the
# serial path, multi-tile run-to-run deterministic and prefetch-
# invisible, within a numeric envelope of serial.
echo "== tier1: cargo test -q --test pipeline_identity exec_tiles =="
cargo test -q --test pipeline_identity exec_tiles

# Parallel state-scatter identity by name: the per-shard consumer
# scatter (memory rows + mailbox ring) must be bitwise-equal to the
# serial replay, hot cache off and on.
echo "== tier1: parallel shard-scatter identity =="
cargo test -q --lib par_shard

# Fault-tolerance acceptance by name: kill-and-resume bitwise identity,
# supervised producers, checkpoint integrity under injected faults, and
# the divergence rollback guard.
echo "== tier1: cargo test -q --test fault_tolerance =="
cargo test -q --test fault_tolerance

# Out-of-core acceptance: disk-container identity + the in-RAM identity
# of the disk-backed trainer, then the streamed-build memory bound run
# alone by name (VmHWM and the allocation counters are process-global,
# so the bound test must own its process — hence `#[ignore]` + `--exact`).
echo "== tier1: cargo test -q --test out_of_core =="
cargo test -q --test out_of_core
echo "== tier1: cargo test -q --test pipeline_identity out_of_core =="
cargo test -q --test pipeline_identity out_of_core
echo "== tier1: streamed-build RSS/allocation bound =="
cargo test -q --release --test out_of_core streamed_build_stays_bounded -- --ignored --exact

# SIMD kernel agreement by name: the explicit-lane kernels must stay
# bitwise-identical (accumulate family) / ULP-bounded (reduction family)
# against their scalar twins.
echo "== tier1: simd kernel agreement =="
cargo test -q --lib runtime::simd

# Production-width gates: the quick width-100 tests run in the debug
# suite below; the expensive ones (finite-difference gradcheck,
# convergence AP, throughput smoke) run here in release mode by name.
echo "== tier1: width-100 gradcheck =="
cargo test -q --release --test width100 width100_gradients_match_finite_differences \
  -- --ignored --exact
echo "== tier1: width-100 convergence =="
cargo test -q --release --test width100 width100_convergence_clears_ap_gate -- --ignored --exact
echo "== tier1: width-100 throughput smoke =="
cargo test -q --release --test width100 width100_throughput_smoke -- --ignored --exact

# Zero-allocation guarantee (width 8, sharded, and width 100) — a single
# test so the process-global counter stays honest.
echo "== tier1: cargo test -q --test alloc_train =="
cargo test -q --test alloc_train

echo "== tier1: cargo test -q =="
cargo test -q

if [ "$RUN_FMT" = 1 ]; then
  if cargo fmt --version >/dev/null 2>&1; then
    if [ "${TGL_FMT_ADVISORY:-0}" = 1 ]; then
      echo "== tier1: cargo fmt --check (advisory via TGL_FMT_ADVISORY=1) =="
      cargo fmt --check || echo "tier1: WARNING — formatting drift (advisory)" >&2
    else
      # Hard gate: the seed formatting was normalized; run
      # `cargo fmt` to fix drift, or set TGL_FMT_ADVISORY=1 to downgrade
      # (e.g. on machines whose rustfmt version disagrees).
      echo "== tier1: cargo fmt --check =="
      cargo fmt --check
    fi
  else
    echo "tier1: rustfmt unavailable, skipping fmt gate" >&2
  fi
fi

if [ "$RUN_CLIPPY" = 1 ]; then
  if cargo clippy --version >/dev/null 2>&1; then
    echo "== tier1: cargo clippy --all-targets -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
  else
    echo "tier1: clippy unavailable, skipping lint gate" >&2
  fi
fi

echo "tier1: OK"
