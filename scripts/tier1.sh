#!/usr/bin/env bash
# Tier-1 verification: build, tests, formatting, lints.
#
# Usage: scripts/tier1.sh [--no-clippy] [--no-fmt]
# Mirrors ROADMAP.md's "Tier-1 verify" contract plus the fmt/clippy gates;
# CI and pre-PR checks should both run this script.
set -euo pipefail
cd "$(dirname "$0")/.."

RUN_CLIPPY=1
RUN_FMT=1
for arg in "$@"; do
  case "$arg" in
    --no-clippy) RUN_CLIPPY=0 ;;
    --no-fmt) RUN_FMT=0 ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

if ! command -v cargo >/dev/null 2>&1; then
  echo "tier1: cargo not found on PATH — install a Rust toolchain first" >&2
  exit 3
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

if [ "$RUN_FMT" = 1 ]; then
  if cargo fmt --version >/dev/null 2>&1; then
    echo "== tier1: cargo fmt --check (advisory) =="
    # Advisory until the pre-rustfmt seed formatting is normalized in one
    # dedicated sweep (ROADMAP open item); new code should be fmt-clean.
    cargo fmt --check || echo "tier1: WARNING — formatting drift (advisory for now)" >&2
  else
    echo "tier1: rustfmt unavailable, skipping fmt gate" >&2
  fi
fi

if [ "$RUN_CLIPPY" = 1 ]; then
  if cargo clippy --version >/dev/null 2>&1; then
    echo "== tier1: cargo clippy --all-targets -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
  else
    echo "tier1: clippy unavailable, skipping lint gate" >&2
  fi
fi

echo "tier1: OK"
